//! TOML-subset parser.
//!
//! No `serde`/`toml` crates are available offline, so the config system
//! rests on this small parser. Supported grammar (the subset our config
//! files use):
//!
//! * `[section]` and `[section.subsection]` headers
//! * `key = value` with value ∈ {string "…", integer, float, bool}
//! * inline arrays of scalars `[1, 2, 3]`
//! * `#` comments and blank lines
//!
//! Keys are flattened to dotted paths (`section.key`) into an ordered map.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed scalar or array value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    /// String content, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Integer content (ints only; floats are not silently truncated).
    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    /// Float content; integers widen to float.
    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    /// Boolean content.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Parse error with 1-based line number.
#[derive(Debug)]
pub struct ParseError {
    pub line: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "toml parse error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for ParseError {}

/// Ordered dotted-path → value document.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Doc {
    map: BTreeMap<String, Value>,
}

impl Doc {
    /// Parse a document from text.
    pub fn parse(text: &str) -> Result<Doc, ParseError> {
        let mut map = BTreeMap::new();
        let mut section = String::new();
        for (ln, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            let lineno = ln + 1;
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest.strip_suffix(']').ok_or_else(|| ParseError {
                    line: lineno,
                    msg: "unterminated section header".into(),
                })?;
                let name = name.trim();
                if name.is_empty() {
                    return Err(ParseError {
                        line: lineno,
                        msg: "empty section name".into(),
                    });
                }
                section = name.to_string();
                continue;
            }
            let eq = line.find('=').ok_or_else(|| ParseError {
                line: lineno,
                msg: format!("expected `key = value`, got `{line}`"),
            })?;
            let key = line[..eq].trim();
            if key.is_empty() {
                return Err(ParseError {
                    line: lineno,
                    msg: "empty key".into(),
                });
            }
            let val = parse_value(line[eq + 1..].trim(), lineno)?;
            let path = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            map.insert(path, val);
        }
        Ok(Doc { map })
    }

    /// Look up a dotted path.
    pub fn get(&self, path: &str) -> Option<&Value> {
        self.map.get(path)
    }

    /// String at path.
    pub fn str(&self, path: &str) -> Option<&str> {
        self.get(path).and_then(Value::as_str)
    }

    /// Integer at path.
    pub fn int(&self, path: &str) -> Option<i64> {
        self.get(path).and_then(Value::as_int)
    }

    /// Float at path (ints widen).
    pub fn float(&self, path: &str) -> Option<f64> {
        self.get(path).and_then(Value::as_float)
    }

    /// Bool at path.
    pub fn bool(&self, path: &str) -> Option<bool> {
        self.get(path).and_then(Value::as_bool)
    }

    /// All `(path, value)` pairs in order.
    pub fn iter(&self) -> impl Iterator<Item = (&String, &Value)> {
        self.map.iter()
    }

    /// Insert / override a value (used by CLI `--set section.key=value`).
    pub fn set(&mut self, path: &str, v: Value) {
        self.map.insert(path.to_string(), v);
    }
}

fn strip_comment(line: &str) -> &str {
    // A `#` inside a quoted string does not start a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str, line: usize) -> Result<Value, ParseError> {
    let err = |msg: String| ParseError { line, msg };
    if s.is_empty() {
        return Err(err("missing value".into()));
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner
            .strip_suffix('"')
            .ok_or_else(|| err("unterminated string".into()))?;
        return Ok(Value::Str(inner.to_string()));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| err("unterminated array".into()))?;
        let mut items = Vec::new();
        let trimmed = inner.trim();
        if !trimmed.is_empty() {
            for part in trimmed.split(',') {
                let part = part.trim();
                if part.is_empty() {
                    continue; // trailing comma
                }
                items.push(parse_value(part, line)?);
            }
        }
        return Ok(Value::Array(items));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.replace('_', "").parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.replace('_', "").parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(err(format!("cannot parse value `{s}`")))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_scalars() {
        let d = Doc::parse(
            r#"
            # top comment
            name = "noloco"
            [model]
            hidden = 768
            lr = 6e-4            # inline comment
            tied = false
            [outer.noloco]
            alpha = 0.5
            "#,
        )
        .unwrap();
        assert_eq!(d.str("name"), Some("noloco"));
        assert_eq!(d.int("model.hidden"), Some(768));
        assert!((d.float("model.lr").unwrap() - 6e-4).abs() < 1e-12);
        assert_eq!(d.bool("model.tied"), Some(false));
        assert!((d.float("outer.noloco.alpha").unwrap() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn ints_widen_to_float_not_vice_versa() {
        let d = Doc::parse("a = 3\nb = 3.5\n").unwrap();
        assert_eq!(d.float("a"), Some(3.0));
        assert_eq!(d.int("b"), None);
    }

    #[test]
    fn arrays() {
        let d = Doc::parse("xs = [1, 2, 3]\nys = [0.5, 1.5,]\nzs = []\n").unwrap();
        match d.get("xs").unwrap() {
            Value::Array(v) => assert_eq!(v.len(), 3),
            _ => panic!(),
        }
        match d.get("ys").unwrap() {
            Value::Array(v) => assert_eq!(v.len(), 2),
            _ => panic!(),
        }
        match d.get("zs").unwrap() {
            Value::Array(v) => assert!(v.is_empty()),
            _ => panic!(),
        }
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let d = Doc::parse("s = \"a#b\"\n").unwrap();
        assert_eq!(d.str("s"), Some("a#b"));
    }

    #[test]
    fn underscored_numbers() {
        let d = Doc::parse("big = 128_000\n").unwrap();
        assert_eq!(d.int("big"), Some(128_000));
    }

    #[test]
    fn errors_carry_line_numbers() {
        let e = Doc::parse("ok = 1\nbroken line\n").unwrap_err();
        assert_eq!(e.line, 2);
        let e = Doc::parse("[unterminated\n").unwrap_err();
        assert_eq!(e.line, 1);
        let e = Doc::parse("k = \"oops\n").unwrap_err();
        assert_eq!(e.line, 1);
    }

    #[test]
    fn set_overrides() {
        let mut d = Doc::parse("a = 1\n").unwrap();
        d.set("a", Value::Int(2));
        assert_eq!(d.int("a"), Some(2));
    }
}
