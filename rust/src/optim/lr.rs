//! Learning-rate schedule: linear warm-up then cosine decay (§4).
//!
//! The paper warms up for 1000 steps and decays the LR "by one magnitude
//! compared to the maximum" with a cosine schedule. The schedule matters
//! beyond convergence speed here: Theorem 1 says replica variance ∝ ω², so
//! the decaying schedule is the paper's mechanism for *eventual weight
//! consistency* (Fig. 3B shows Pearson r = 0.91–0.97 between σ and LR).

/// Warm-up + cosine decay schedule.
#[derive(Clone, Copy, Debug)]
pub struct LrSchedule {
    /// Peak learning rate (after warm-up).
    pub peak: f64,
    /// Warm-up length in steps.
    pub warmup: usize,
    /// Total step budget.
    pub total: usize,
    /// Final LR as a fraction of peak (paper: 0.1).
    pub floor_frac: f64,
}

impl LrSchedule {
    /// Paper defaults: floor at `peak / 10`.
    pub fn new(peak: f64, warmup: usize, total: usize) -> LrSchedule {
        LrSchedule {
            peak,
            warmup,
            total,
            floor_frac: 0.1,
        }
    }

    /// LR at `step` (0-based).
    pub fn at(&self, step: usize) -> f64 {
        if self.warmup > 0 && step < self.warmup {
            return self.peak * (step + 1) as f64 / self.warmup as f64;
        }
        let span = self.total.saturating_sub(self.warmup).max(1);
        let t = ((step - self.warmup).min(span)) as f64 / span as f64;
        let floor = self.peak * self.floor_frac;
        floor + 0.5 * (self.peak - floor) * (1.0 + (std::f64::consts::PI * t).cos())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_is_linear_to_peak() {
        let s = LrSchedule::new(1.0, 10, 100);
        assert!((s.at(0) - 0.1).abs() < 1e-12);
        assert!((s.at(4) - 0.5).abs() < 1e-12);
        assert!((s.at(9) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn decays_to_floor_by_one_magnitude() {
        let s = LrSchedule::new(6e-4, 1000, 25_000);
        assert!((s.at(1000) - 6e-4).abs() < 1e-6);
        let end = s.at(24_999);
        assert!((end - 6e-5).abs() / 6e-5 < 0.01, "end={end}");
    }

    #[test]
    fn monotone_decay_after_warmup() {
        let s = LrSchedule::new(1.0, 5, 200);
        let mut prev = f64::INFINITY;
        for step in 5..200 {
            let lr = s.at(step);
            assert!(lr <= prev + 1e-12);
            prev = lr;
        }
    }

    #[test]
    fn no_warmup_edge_case() {
        let s = LrSchedule::new(1.0, 0, 10);
        assert!((s.at(0) - 1.0).abs() < 1e-12);
        assert!(s.at(9) >= 0.1 - 1e-12);
    }

    #[test]
    fn beyond_total_clamps_at_floor() {
        let s = LrSchedule::new(1.0, 0, 10);
        assert!((s.at(50) - 0.1).abs() < 1e-9);
    }
}
