//! Optimizers — inner (Adam, SGD) and outer (DiLoCo Nesterov, NoLoCo
//! modified Nesterov, Eq. 2).
//!
//! These are the *host-side* reference implementations: the quadratic
//! convergence harness ([`crate::quad`]), the pure-Rust simulation paths
//! and the property tests run on them. On the PJRT hot path the same
//! updates execute as XLA artifacts (`adam.hlo.txt`,
//! `outer_noloco.hlo.txt`) compiled from `python/compile/model.py`; the
//! integration tests cross-check artifact output against these
//! implementations.

mod adam;
mod lr;
mod outer;
mod sgd;

pub use adam::Adam;
pub use lr::LrSchedule;
pub use outer::{DilocoOuter, NolocoOuter, OuterState};
pub use sgd::Sgd;

use crate::tensor::Tensor;

/// Clip a gradient set to a global L2 norm (paper §4: "gradient clipping
/// for gradients larger than unity"). Returns the pre-clip norm.
pub fn clip_global_norm(grads: &mut [Tensor], max_norm: f64) -> f64 {
    let norm_sq: f64 = grads.iter().map(|g| g.norm_sq()).sum();
    let norm = norm_sq.sqrt();
    if norm > max_norm && norm > 0.0 {
        let s = (max_norm / norm) as f32;
        for g in grads.iter_mut() {
            g.scale(s);
        }
    }
    norm
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clip_leaves_small_gradients_alone() {
        let mut gs = vec![Tensor::from_slice(&[0.3, 0.4])]; // norm 0.5
        let n = clip_global_norm(&mut gs, 1.0);
        assert!((n - 0.5).abs() < 1e-6);
        assert_eq!(gs[0].as_slice(), &[0.3, 0.4]);
    }

    #[test]
    fn clip_rescales_large_gradients_to_threshold() {
        let mut gs = vec![
            Tensor::from_slice(&[3.0, 0.0]),
            Tensor::from_slice(&[0.0, 4.0]),
        ]; // global norm 5
        let n = clip_global_norm(&mut gs, 1.0);
        assert!((n - 5.0).abs() < 1e-6);
        let new_norm: f64 = gs.iter().map(|g| g.norm_sq()).sum::<f64>().sqrt();
        assert!((new_norm - 1.0).abs() < 1e-5);
    }

    #[test]
    fn property_clip_never_increases_norm() {
        crate::prop::run("clip never increases global norm", 100, |g| {
            let k = g.usize_in(1, 4);
            let mut gs: Vec<Tensor> = (0..k)
                .map(|_| {
                    let n = g.usize_in(1, 20).max(1);
                    Tensor::from_slice(&g.vec_normal(n, 3.0))
                })
                .collect();
            let before: f64 = gs.iter().map(|t| t.norm_sq()).sum::<f64>().sqrt();
            let max = g.f64_in(0.1, 2.0);
            clip_global_norm(&mut gs, max);
            let after: f64 = gs.iter().map(|t| t.norm_sq()).sum::<f64>().sqrt();
            assert!(after <= before + 1e-6);
            assert!(after <= max + 1e-4);
        });
    }
}
