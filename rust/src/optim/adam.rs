//! Adam (Kingma & Ba) — the paper's inner optimizer for all LM
//! experiments (§4), with bias correction.

use crate::tensor::Tensor;

/// Adam state over a parameter list.
#[derive(Clone, Debug)]
pub struct Adam {
    /// First-moment EMA coefficient.
    pub beta1: f64,
    /// Second-moment EMA coefficient.
    pub beta2: f64,
    /// Denominator fuzz.
    pub eps: f64,
    m: Vec<Tensor>,
    v: Vec<Tensor>,
    t: u64,
}

impl Adam {
    /// Fresh state shaped like `params`, default (0.9, 0.95) betas — the
    /// usual LLM setting.
    pub fn new(params: &[Tensor]) -> Adam {
        Adam::with_betas(params, 0.9, 0.95, 1e-8)
    }

    /// Fresh state with explicit hyper-parameters.
    pub fn with_betas(params: &[Tensor], beta1: f64, beta2: f64, eps: f64) -> Adam {
        Adam {
            beta1,
            beta2,
            eps,
            m: params.iter().map(|p| Tensor::zeros(p.shape())).collect(),
            v: params.iter().map(|p| Tensor::zeros(p.shape())).collect(),
            t: 0,
        }
    }

    /// Steps taken so far.
    pub fn steps(&self) -> u64 {
        self.t
    }

    /// Borrow the moment buffers (shipped to the XLA adam artifact).
    pub fn moments(&self) -> (&[Tensor], &[Tensor]) {
        (&self.m, &self.v)
    }

    /// Mutable moment buffers (written back from the XLA adam artifact).
    pub fn moments_mut(&mut self) -> (&mut [Tensor], &mut [Tensor]) {
        (&mut self.m, &mut self.v)
    }

    /// Record that one external (artifact-side) step happened.
    pub fn bump(&mut self) {
        self.t += 1;
    }

    /// One host-side update with learning rate `lr`.
    pub fn step(&mut self, params: &mut [Tensor], grads: &[Tensor], lr: f64) {
        assert_eq!(params.len(), grads.len());
        self.t += 1;
        let b1 = self.beta1;
        let b2 = self.beta2;
        let bc1 = 1.0 - b1.powi(self.t as i32);
        let bc2 = 1.0 - b2.powi(self.t as i32);
        for ((p, g), (m, v)) in params
            .iter_mut()
            .zip(grads)
            .zip(self.m.iter_mut().zip(self.v.iter_mut()))
        {
            let (ps, gs) = (p.as_mut_slice(), g.as_slice());
            let (ms, vs) = (m.as_mut_slice(), v.as_mut_slice());
            for i in 0..ps.len() {
                let gi = gs[i] as f64;
                let mi = b1 * ms[i] as f64 + (1.0 - b1) * gi;
                let vi = b2 * vs[i] as f64 + (1.0 - b2) * gi * gi;
                ms[i] = mi as f32;
                vs[i] = vi as f32;
                let mhat = mi / bc1;
                let vhat = vi / bc2;
                ps[i] -= (lr * mhat / (vhat.sqrt() + self.eps)) as f32;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_step_moves_by_about_lr() {
        // With bias correction, |Δp| ≈ lr for any gradient scale on step 1.
        for scale in [1e-3f32, 1.0, 1e3] {
            let mut p = vec![Tensor::from_slice(&[0.0])];
            let g = vec![Tensor::from_slice(&[scale])];
            let mut opt = Adam::new(&p);
            opt.step(&mut p, &g, 0.01);
            let d = p[0].as_slice()[0].abs();
            assert!((d - 0.01).abs() < 1e-4, "scale={scale} d={d}");
        }
    }

    #[test]
    fn converges_on_quadratic_bowl() {
        let mut p = vec![Tensor::from_slice(&[5.0, -3.0])];
        let mut opt = Adam::new(&p);
        for _ in 0..800 {
            let g = vec![Tensor::from_vec(
                p[0].as_slice().iter().map(|x| 2.0 * x).collect(),
                &[2],
            )];
            opt.step(&mut p, &g, 0.05);
        }
        assert!(p[0].norm() < 1e-2, "norm={}", p[0].norm());
    }

    #[test]
    fn moment_buffers_track_state() {
        let mut p = vec![Tensor::from_slice(&[1.0])];
        let g = vec![Tensor::from_slice(&[2.0])];
        let mut opt = Adam::new(&p);
        opt.step(&mut p, &g, 0.1);
        let (m, v) = opt.moments();
        assert!((m[0].as_slice()[0] - 0.2).abs() < 1e-6); // (1-0.9)*2
        assert!((v[0].as_slice()[0] - 0.2).abs() < 1e-6); // (1-0.95)*4
        assert_eq!(opt.steps(), 1);
    }

    #[test]
    fn direction_is_descent_for_fresh_state() {
        crate::prop::run("adam step opposes the gradient (step 1)", 60, |gn| {
            let n = gn.usize_in(1, 16).max(1);
            let g = Tensor::from_slice(&gn.vec_normal(n, 1.0));
            let mut p = vec![Tensor::zeros(&[n])];
            let mut opt = Adam::new(&p);
            opt.step(&mut p, std::slice::from_ref(&g), 0.01);
            // Δp · g < 0 unless g == 0.
            let dot = p[0].dot(&g);
            if g.norm() > 1e-6 {
                assert!(dot < 0.0, "dot={dot}");
            }
        });
    }
}
