//! Outer optimizers: DiLoCo Nesterov and the NoLoCo modified Nesterov
//! momentum of Eq. 2.
//!
//! ## Sign convention
//!
//! The paper defines the outer gradient as `Δ_{t,i} = θ_{t+1,i} − φ_{t,i}`
//! (Eq. 1, pointing from slow weights toward the improved fast weights)
//! and writes Eq. 2 with `−β/n · ΣΔ`. Its own convergence appendix,
//! however, uses `E(δ_t) = α E(δ_{t−1}) + β E(Δ_t)` (Eq. 32) — and only
//! that sign makes `φ += δ` move *toward* the optimum (with α = γ = 0,
//! β = 1, the update must reduce to lookahead's `φ ← mean(θ)`). We follow
//! the appendix / working sign:
//!
//! ```text
//! δ_{t,i} = α δ_{t−1,i} + (β/n) Σ_j Δ_{t,j} − γ (φ_{t,i} − (1/n) Σ_j φ_{t,j})
//! φ_{t+1,i} = φ_{t,i} + δ_{t,i}                                  (Eq. 3)
//! ```
//!
//! DiLoCo is the n = N, γ = 0 special case, with the mean over Δ computed
//! by all-reduce instead of a random subgroup.
//!
//! These host-side tensor optimizers power the quadratic Theorem-1
//! harness ([`crate::quad`]); the transformer trainers run the same
//! updates inside fused XLA artifacts, dispatched through the
//! [`crate::train::SyncStrategy`] impls (which also decide *who*
//! contributes to the group sums — all-reduce rows vs. gossip pairs
//! drawn by a [`crate::train::PairingPolicy`]).

use crate::tensor::Tensor;

/// Per-replica slow-weight state shared by both outer optimizers.
#[derive(Clone, Debug)]
pub struct OuterState {
    /// Slow weights φ.
    pub phi: Vec<Tensor>,
    /// Momentum δ (zero-initialized; App. B assumes δ₀ ≡ 0).
    pub delta: Vec<Tensor>,
}

impl OuterState {
    /// Initialize from the starting weights (φ₀ = initial params).
    pub fn new(initial: &[Tensor]) -> OuterState {
        OuterState {
            phi: initial.to_vec(),
            delta: initial.iter().map(|t| Tensor::zeros(t.shape())).collect(),
        }
    }

    /// The outer gradient Δ = θ − φ for this replica (Eq. 1).
    pub fn outer_grad(&self, theta: &[Tensor]) -> Vec<Tensor> {
        assert_eq!(theta.len(), self.phi.len());
        theta
            .iter()
            .zip(&self.phi)
            .map(|(t, p)| {
                let mut d = t.clone();
                d.sub_assign(p);
                d
            })
            .collect()
    }
}

/// DiLoCo outer optimizer (Douillard et al. 2023): Nesterov momentum over
/// the all-reduced mean outer gradient. Paper setting: α = 0.3, β = 0.7,
/// outer step every 100 inner steps.
#[derive(Clone, Copy, Debug)]
pub struct DilocoOuter {
    /// Momentum α.
    pub alpha: f64,
    /// Outer learning rate β.
    pub beta: f64,
}

impl DilocoOuter {
    /// Apply one outer step given the *already all-reduced* mean outer
    /// gradient. After this, fast weights should be reset to `state.phi`.
    pub fn step(&self, state: &mut OuterState, mean_outer_grad: &[Tensor]) {
        assert_eq!(state.phi.len(), mean_outer_grad.len());
        let (a, b) = (self.alpha as f32, self.beta as f32);
        for (k, d) in mean_outer_grad.iter().enumerate() {
            state.delta[k].scale(a);
            state.delta[k].axpy(b, d);
            let dk = state.delta[k].clone();
            state.phi[k].add_assign(&dk);
        }
    }
}

/// NoLoCo outer optimizer (§3.2): the modified Nesterov update over a
/// random subgroup (minimum size n = 2 in all paper experiments), with the
/// weight-consensus term −γ(φ_i − φ̄). Paper setting: α = 0.5, β = 0.7,
/// outer step every 50 inner steps.
#[derive(Clone, Copy, Debug)]
pub struct NolocoOuter {
    /// Momentum α.
    pub alpha: f64,
    /// Outer learning rate β.
    pub beta: f64,
    /// Consensus coefficient γ; must satisfy the Eq. 74 window
    /// (see [`crate::config::OuterConfig::gamma_window`]).
    pub gamma: f64,
}

impl NolocoOuter {
    /// One gossip outer step for this replica, given
    ///
    /// * `theta` — this replica's fast weights after m inner steps,
    /// * `group_deltas` — outer gradients Δ of *every* group member
    ///   (including this replica's own, in any order),
    /// * `group_phis` — slow weights φ of every group member (ditto).
    ///
    /// For the paper's n = 2 this is one peer exchange: each side ships
    /// (Δ_j, φ_j) — the φ can be sent early, overlapping communication
    /// with compute, as §3.2 notes.
    pub fn step_group(
        &self,
        state: &mut OuterState,
        theta: &[Tensor],
        group_deltas: &[Vec<Tensor>],
        group_phis: &[Vec<Tensor>],
    ) {
        let n = group_deltas.len();
        assert!(n >= 1);
        assert_eq!(n, group_phis.len());
        let _ = theta;
        let (a, b, g) = (self.alpha as f32, self.beta as f32, self.gamma as f32);
        let inv_n = 1.0 / n as f32;
        // Split-borrow φ and δ (disjoint fields) so the update runs
        // clone-free — the old per-tensor clones dominated this path at
        // multi-million-parameter sizes (EXPERIMENTS.md §Perf).
        let OuterState { phi, delta } = state;
        for k in 0..phi.len() {
            // δ ← α δ
            delta[k].scale(a);
            // δ += (β/n) Σ_j Δ_j
            for dj in group_deltas {
                delta[k].axpy(b * inv_n, &dj[k]);
            }
            // δ −= γ (φ_i − mean_j φ_j)
            delta[k].axpy(-g, &phi[k]);
            for pj in group_phis {
                delta[k].axpy(g * inv_n, &pj[k]);
            }
            // φ += δ
            phi[k].add_assign(&delta[k]);
        }
    }

    /// Convenience for the n = 2 case: this replica + one peer.
    pub fn step_pair(
        &self,
        state: &mut OuterState,
        theta: &[Tensor],
        my_delta: &[Tensor],
        peer_delta: &[Tensor],
        peer_phi: &[Tensor],
    ) {
        let my_phi = state.phi.clone();
        self.step_group(
            state,
            theta,
            &[my_delta.to_vec(), peer_delta.to_vec()],
            &[my_phi, peer_phi.to_vec()],
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rngx::Pcg64;

    fn randp(rng: &mut Pcg64, shapes: &[&[usize]]) -> Vec<Tensor> {
        shapes.iter().map(|s| Tensor::randn(s, 1.0, rng)).collect()
    }

    #[test]
    fn diloco_with_zero_momentum_is_lookahead() {
        // α=0, β=1: φ ← φ + mean(θ−φ) = mean(θ).
        let phi = vec![Tensor::from_slice(&[1.0, 2.0])];
        let theta = vec![Tensor::from_slice(&[3.0, 6.0])];
        let mut st = OuterState::new(&phi);
        let d = st.outer_grad(&theta);
        DilocoOuter { alpha: 0.0, beta: 1.0 }.step(&mut st, &d);
        assert_eq!(st.phi[0].as_slice(), &[3.0, 6.0]);
    }

    #[test]
    fn diloco_momentum_accumulates() {
        let phi = vec![Tensor::from_slice(&[0.0])];
        let mut st = OuterState::new(&phi);
        let opt = DilocoOuter { alpha: 0.5, beta: 1.0 };
        let d = vec![Tensor::from_slice(&[1.0])];
        opt.step(&mut st, &d); // δ=1, φ=1
        assert_eq!(st.phi[0].as_slice(), &[1.0]);
        opt.step(&mut st, &d); // δ=1.5, φ=2.5
        assert_eq!(st.phi[0].as_slice(), &[2.5]);
    }

    #[test]
    fn noloco_full_group_gamma_zero_matches_diloco() {
        // With the group = all replicas and γ = 0, Eq. 2 degenerates to
        // the DiLoCo momentum (the paper notes this below Eq. 2).
        let mut rng = Pcg64::seed_from_u64(21);
        let shapes: &[&[usize]] = &[&[4], &[2, 3]];
        let phi = randp(&mut rng, shapes);
        let thetas: Vec<Vec<Tensor>> = (0..3).map(|_| randp(&mut rng, shapes)).collect();

        // DiLoCo on the mean outer grad.
        let mut st_d = OuterState::new(&phi);
        let mut mean_d: Vec<Tensor> = shapes.iter().map(|s| Tensor::zeros(s)).collect();
        for th in &thetas {
            for (m, d) in mean_d.iter_mut().zip(st_d.outer_grad(th)) {
                m.axpy(1.0 / 3.0, &d);
            }
        }
        DilocoOuter { alpha: 0.4, beta: 0.7 }.step(&mut st_d, &mean_d);

        // NoLoCo with the whole world as the group (all φ identical).
        let mut st_n = OuterState::new(&phi);
        let deltas: Vec<Vec<Tensor>> = thetas.iter().map(|th| st_n.outer_grad(th)).collect();
        let phis: Vec<Vec<Tensor>> = (0..3).map(|_| phi.clone()).collect();
        NolocoOuter { alpha: 0.4, beta: 0.7, gamma: 0.9 } // γ inert: φ's equal
            .step_group(&mut st_n, &thetas[0], &deltas, &phis);

        for (a, b) in st_d.phi.iter().zip(&st_n.phi) {
            for (x, y) in a.as_slice().iter().zip(b.as_slice()) {
                assert!((x - y).abs() < 1e-5, "{x} vs {y}");
            }
        }
    }

    #[test]
    fn consensus_term_pulls_replicas_together() {
        // β = 0 isolates the γ term: repeated pair steps must shrink the
        // gap between two replicas' φ.
        let opt = NolocoOuter { alpha: 0.0, beta: 0.0, gamma: 0.8 };
        let mut a = OuterState::new(&[Tensor::from_slice(&[0.0])]);
        let mut b = OuterState::new(&[Tensor::from_slice(&[10.0])]);
        let zero = vec![Tensor::from_slice(&[0.0])];
        for _ in 0..6 {
            let pa = a.phi.clone();
            let pb = b.phi.clone();
            opt.step_pair(&mut a, &zero, &zero, &zero, &pb);
            opt.step_pair(&mut b, &zero, &zero, &zero, &pa);
        }
        let gap = (a.phi[0].as_slice()[0] - b.phi[0].as_slice()[0]).abs();
        assert!(gap < 1.0, "gap={gap}");
    }

    #[test]
    fn identical_replicas_make_gamma_term_vanish() {
        // If φ_i = φ_j the consensus term is exactly zero: γ must not
        // perturb a converged ensemble.
        let mut rng = Pcg64::seed_from_u64(22);
        let phi = randp(&mut rng, &[&[8]]);
        let theta = randp(&mut rng, &[&[8]]);
        let run = |gamma: f64| {
            let mut st = OuterState::new(&phi);
            let d = st.outer_grad(&theta);
            let opt = NolocoOuter { alpha: 0.3, beta: 0.7, gamma };
            opt.step_pair(&mut st, &theta, &d, &d, &phi.clone());
            st.phi[0].as_slice().to_vec()
        };
        let lo = run(0.0);
        let hi = run(1.2);
        for (x, y) in lo.iter().zip(&hi) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn phi_can_move_toward_fast_weights() {
        // One NoLoCo pair step with positive β moves φ toward the fast
        // weights (descent direction for the outer problem).
        let phi = vec![Tensor::from_slice(&[0.0, 0.0])];
        let theta = vec![Tensor::from_slice(&[1.0, -2.0])];
        let mut st = OuterState::new(&phi);
        let d = st.outer_grad(&theta);
        let opt = NolocoOuter { alpha: 0.5, beta: 0.7, gamma: 0.9 };
        opt.step_pair(&mut st, &theta, &d, &d, &phi.clone());
        let p = st.phi[0].as_slice();
        assert!(p[0] > 0.0 && p[0] < 1.0);
        assert!(p[1] < 0.0 && p[1] > -2.0);
    }

    #[test]
    fn property_average_phi_is_invariant_under_pure_consensus() {
        // With β = 0 and any α=0 gossip pairing, the *mean* of the group's
        // slow weights is preserved by a simultaneous pair update: the γ
        // term is antisymmetric within the pair.
        crate::prop::run("gossip consensus preserves pair mean", 80, |g| {
            let n = g.usize_in(2, 24).max(2);
            let opt = NolocoOuter { alpha: 0.0, beta: 0.0, gamma: g.f64_in(0.1, 1.3) };
            let mut states: Vec<OuterState> = (0..2)
                .map(|_| OuterState::new(&[Tensor::from_slice(&g.vec_normal(n, 2.0))]))
                .collect();
            let zero = vec![Tensor::zeros(&[n])];
            let before: f64 =
                states.iter().map(|s| s.phi[0].mean()).sum::<f64>() / 2.0;
            let (a_phi, b_phi) = (states[0].phi.clone(), states[1].phi.clone());
            opt.step_pair(&mut states[0], &zero, &zero, &zero, &b_phi);
            opt.step_pair(&mut states[1], &zero, &zero, &zero, &a_phi);
            let after: f64 =
                states.iter().map(|s| s.phi[0].mean()).sum::<f64>() / 2.0;
            assert!((before - after).abs() < 1e-5, "{before} vs {after}");
        });
    }
}
