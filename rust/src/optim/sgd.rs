//! Plain stochastic gradient descent.
//!
//! The convergence analysis (App. A.1) assumes the inner optimizer is SGD
//! with a constant learning rate ω; the Theorem-1 harness uses this
//! implementation so the empirical variance law V(φ) ∝ ω² is tested
//! against exactly the optimizer the proof assumes.

use crate::tensor::Tensor;

/// Constant-rate SGD over a parameter list.
#[derive(Clone, Debug)]
pub struct Sgd {
    /// Learning rate ω.
    pub lr: f64,
}

impl Sgd {
    /// New optimizer with rate `lr`.
    pub fn new(lr: f64) -> Sgd {
        Sgd { lr }
    }

    /// `params -= lr * grads`.
    pub fn step(&self, params: &mut [Tensor], grads: &[Tensor]) {
        assert_eq!(params.len(), grads.len());
        for (p, g) in params.iter_mut().zip(grads) {
            p.axpy(-(self.lr as f32), g);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn descends_a_quadratic() {
        // f(x) = x², grad 2x, from x0=1 with lr 0.1: x_{k+1} = 0.8 x_k.
        let mut p = vec![Tensor::from_slice(&[1.0])];
        let opt = Sgd::new(0.1);
        for _ in 0..10 {
            let g = vec![Tensor::from_slice(&[2.0 * p[0].as_slice()[0]])];
            opt.step(&mut p, &g);
        }
        let want = 0.8f32.powi(10);
        assert!((p[0].as_slice()[0] - want).abs() < 1e-6);
    }
}
