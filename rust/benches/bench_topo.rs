//! Topology + elastic-membership benchmarks: per-message transfer
//! sampling across the three network presets, payload-aware collective
//! cost models on a WAN, the shared-seed derivations (live route
//! plans, churn masks) that sit on the trainers' hot path, and the
//! gated-vs-streamed outer-sync comparison (overlap hiding ratio).
//!
//! `cargo bench --bench bench_topo`

use noloco::bench::{bench_row, gated_vs_streamed_pair_sync, lockstep_vs_async_idle, section};
use noloco::collective::{
    pair_average_time_bytes, ring_all_reduce_time_bytes, tree_all_reduce_time_bytes,
    tree_all_reduce_time_over,
};
use noloco::config::{NetPreset, NetTopoConfig, Routing};
use noloco::net::topo::ChurnSchedule;
use noloco::net::{SimClock, Topology};
use noloco::rngx::Pcg64;
use noloco::routing::RoutePlan;
use noloco::train::{BandwidthAwarePairing, PairingPolicy, UniformPairing};

fn transfer_sampling() {
    section("per-message transfer sampling (64 nodes, 4 MiB payload)");
    for preset in [
        NetPreset::SingleSwitchLan,
        NetPreset::MultiRegionWan,
        NetPreset::LongTailInternet,
    ] {
        let cfg = NetTopoConfig { preset, ..NetTopoConfig::default() };
        let topo = cfg.build(64, 1);
        let mut rng = Pcg64::seed_from_u64(2);
        bench_row(&format!("transfer_time, preset {preset}"), || {
            let mut acc = 0.0;
            for i in 0..64usize {
                acc += topo.transfer_time(i, (i * 7 + 1) % 64, 4 << 20, &mut rng);
            }
            std::hint::black_box(acc);
        });
    }
}

fn collective_costs() {
    section("payload-aware collective cost models (WAN, 4 MiB payload)");
    let wan = || {
        NetTopoConfig {
            preset: NetPreset::MultiRegionWan,
            regions: 4,
            ..NetTopoConfig::default()
        }
        .build(64, 1)
    };
    bench_row("tree all-reduce cost walk, n=64", || {
        let mut c = SimClock::with_topology(wan(), 3);
        std::hint::black_box(tree_all_reduce_time_bytes(&mut c, 4 << 20));
    });
    bench_row("ring all-reduce cost walk, n=64", || {
        let mut c = SimClock::with_topology(wan(), 4);
        std::hint::black_box(ring_all_reduce_time_bytes(&mut c, 4 << 20));
    });
    bench_row("gossip pair cost walk,    n=64", || {
        let mut c = SimClock::with_topology(wan(), 5);
        std::hint::black_box(pair_average_time_bytes(&mut c, None, 4 << 20));
    });
    bench_row("live-subset tree (48 of 64 live)", || {
        let mut c = SimClock::with_topology(wan(), 6);
        let live: Vec<usize> = (0..64).filter(|&w| w % 4 != 0).collect();
        std::hint::black_box(tree_all_reduce_time_over(&mut c, &live, 4 << 20));
    });
}

fn shared_seed_derivations() {
    section("shared-seed derivations on the trainer hot path");
    let live: Vec<usize> = (0..32).filter(|&r| r % 5 != 0).collect();
    bench_row("RoutePlan::for_step_over, dp=32 pp=4", || {
        let p = RoutePlan::for_step_over(Routing::Random, &live, 32, 4, 9, 1234);
        std::hint::black_box(p.boundaries());
    });
    let schedule = ChurnSchedule::none()
        .leave(10, 3)
        .leave(20, 7)
        .join(30, 3)
        .leave(40, 11)
        .join(50, 7);
    bench_row("ChurnSchedule::live_at, 5 events", || {
        for step in 0..64u64 {
            std::hint::black_box(schedule.live_at(32, step));
        }
    });
}

/// Uniform vs. bandwidth-aware NoLoCo pairing: per-round gossip sync time
/// (the slowest pair's expected transfer of both (Δ, φ) payloads) against
/// consensus distance (replica variance after scalar gossip averaging) on
/// the `wan` and `long-tail` presets — the ROADMAP's consensus/latency
/// trade, made measurable.
fn pairing_comparison() {
    section("uniform vs bandwidth-aware gossip pairing (24 replicas, 4 MiB (Δ, φ))");
    let dp = 24;
    let payload = 2u64 * (4 << 20);
    let rounds = 200u64;
    let presets = [
        ("wan", NetTopoConfig {
            preset: NetPreset::MultiRegionWan,
            regions: 3,
            ..NetTopoConfig::default()
        }),
        ("long-tail", NetTopoConfig {
            preset: NetPreset::LongTailInternet,
            ..NetTopoConfig::default()
        }),
    ];
    println!(
        "  {:<12} {:<18} {:>16} {:>20}",
        "preset", "policy", "mean sync (s)", "consensus distance"
    );
    for (name, cfg) in presets {
        let topo = cfg.build(dp, 11);
        let policies: [(&str, Box<dyn PairingPolicy>); 2] = [
            ("uniform", Box::new(UniformPairing)),
            ("bandwidth-aware", Box::new(BandwidthAwarePairing::new(cfg.build(dp, 11)))),
        ];
        for (pname, policy) in policies {
            let (sync, dist) = pairing_walk(&topo, policy.as_ref(), dp, payload, rounds);
            println!("  {name:<12} {pname:<18} {sync:>16.4} {dist:>20.3e}");
        }
        // Draw cost itself stays off the hot path's critical budget.
        let live: Vec<usize> = (0..dp).collect();
        let ba = BandwidthAwarePairing::new(cfg.build(dp, 11));
        bench_row(&format!("BandwidthAwarePairing::draw, {name}"), || {
            std::hint::black_box(ba.draw(&live, 2, 0, 1234, 9));
        });
    }
}

/// Walk `rounds` gossip rounds under `policy`: returns (mean per-round
/// sync time, final replica variance of the scalar consensus walk).
fn pairing_walk(
    topo: &Topology,
    policy: &dyn PairingPolicy,
    dp: usize,
    payload: u64,
    rounds: u64,
) -> (f64, f64) {
    let live: Vec<usize> = (0..dp).collect();
    // Scalar consensus state: replica r starts at r (maximal spread).
    let mut x: Vec<f64> = (0..dp).map(|r| r as f64).collect();
    let mut sync_sum = 0.0;
    for outer_idx in 1..=rounds {
        let groups = policy.draw(&live, 2, 0, outer_idx, 7);
        let mut round = 0.0f64;
        for g in &groups {
            if g.len() == 2 {
                round = round.max(topo.expected_transfer(g[0], g[1], payload));
                let avg = 0.5 * (x[g[0]] + x[g[1]]);
                x[g[0]] = avg;
                x[g[1]] = avg;
            }
        }
        sync_sum += round;
    }
    let mean = x.iter().sum::<f64>() / dp as f64;
    let var = x.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / dp as f64;
    (sync_sum / rounds as f64, var)
}

/// Gated vs streamed outer sync on the WAN / long-tail presets: the
/// gated cost is the full (Δ, φ) pair exchange at the boundary; the
/// streamed cost is the per-fragment residual left visible after each
/// fragment hides behind one inner phase. The **hiding ratio**
/// `1 − residual / gated` is the fraction of synchronization wall-clock
/// the streaming strategy removes from the critical path.
fn streaming_overlap_comparison() {
    section("gated vs streamed outer sync (24 replicas, 8 MiB (Δ, φ), 4 fragments)");
    let dp = 24;
    let payload = 2u64 * (4 << 20);
    let fragments = 4;
    // One inner phase of compute behind each fragment (~m inner steps).
    let compute = 0.5;
    let rounds = 100u64;
    let presets = [
        ("wan", NetTopoConfig {
            preset: NetPreset::MultiRegionWan,
            regions: 3,
            ..NetTopoConfig::default()
        }),
        ("long-tail", NetTopoConfig {
            preset: NetPreset::LongTailInternet,
            ..NetTopoConfig::default()
        }),
    ];
    println!(
        "  {:<12} {:>14} {:>16} {:>14}",
        "preset", "gated (s)", "streamed resid (s)", "hiding ratio"
    );
    for (name, cfg) in presets {
        let (gated, resid) =
            gated_vs_streamed_pair_sync(&cfg, dp, payload, fragments, compute, rounds);
        let hiding = 1.0 - resid / gated;
        println!("  {name:<12} {gated:>14.4} {resid:>16.4} {hiding:>14.3}");
        assert!(
            resid < gated,
            "streamed residual must undercut the gated sync on {name}: {resid} vs {gated}"
        );
    }
}

/// Lockstep vs asynchronous boundary idle time on the `wan` and
/// `long-tail` presets: per round, every replica draws a log-normal
/// inner-phase compute time, gossip pairs exchange the 8 MiB (Δ, φ)
/// payload, and the shared [`lockstep_vs_async_idle`] walk reports the
/// mean per-worker stall under the gated global barrier vs the
/// bounded-staleness engine's wait-only-for-your-pair discipline. The
/// **stall reduction** `1 − async / lockstep` is the straggler time the
/// async boundary removes from the critical path.
fn boundary_idle_comparison() {
    section("lockstep vs async boundary idle (24 replicas, 8 MiB (Δ, φ), log-normal compute)");
    let dp = 24;
    let payload = 2u64 * (4 << 20);
    let rounds = 200u64;
    let presets = [
        ("wan", NetTopoConfig {
            preset: NetPreset::MultiRegionWan,
            regions: 3,
            ..NetTopoConfig::default()
        }),
        ("long-tail", NetTopoConfig {
            preset: NetPreset::LongTailInternet,
            ..NetTopoConfig::default()
        }),
    ];
    println!(
        "  {:<12} {:>18} {:>16} {:>16}",
        "preset", "lockstep idle (s)", "async idle (s)", "stall reduction"
    );
    for (name, cfg) in presets {
        let (lock, asy) = lockstep_vs_async_idle(&cfg, dp, payload, rounds, None, 11);
        println!(
            "  {name:<12} {lock:>18.4} {asy:>16.4} {:>16.3}",
            1.0 - asy / lock
        );
        assert!(
            asy < lock,
            "the async boundary must reduce straggler stall on {name}: {asy} vs {lock}"
        );
    }
}

/// The O(1000)-replica scale regime (ROADMAP item 3): the shared-seed
/// hot-path derivations and the gossip pair cost stay cheap as the
/// world grows 24 → 1000, while the blocking tree's critical path keeps
/// deepening — the same regime the `noloco perf` scale ladder pins
/// analytically in `BENCH_steps.json`.
fn thousand_replica_scale() {
    section("O(1000)-replica scale regime (WAN, 8 MiB (Δ, φ))");
    let dp = 1000usize;
    let payload = 2u64 * (4 << 20);
    let cfg = NetTopoConfig {
        preset: NetPreset::MultiRegionWan,
        regions: 3,
        ..NetTopoConfig::default()
    };
    let live: Vec<usize> = (0..dp).collect();
    bench_row("UniformPairing::draw, dp=1000", || {
        std::hint::black_box(UniformPairing.draw(&live, 2, 0, 1234, 9));
    });
    bench_row("RoutePlan::for_step_over, dp=1000 pp=1", || {
        let p = RoutePlan::for_step_over(Routing::Random, &live, 1000, 1, 9, 1234);
        std::hint::black_box(p.boundaries());
    });
    // Per-round sync at n = 1000: the gossip pair's cost is O(1) in
    // world size, the blocking tree keeps charging for its depth.
    let mut clock = SimClock::with_topology(cfg.build(dp, 12), 3);
    let tree = tree_all_reduce_time_bytes(&mut clock, payload);
    let mut clock = SimClock::with_topology(cfg.build(dp, 13), 5);
    let pair = pair_average_time_bytes(&mut clock, None, payload);
    println!(
        "  tree all-reduce n=1000: {tree:.4} s   gossip pair mean: {pair:.4} s   ratio {:.1}x",
        tree / pair
    );
    assert!(
        pair < tree,
        "gossip must undercut the 1000-node blocking tree: {pair} vs {tree}"
    );
}

fn main() {
    println!("bench_topo — WAN topology, payload-aware collectives, elastic membership");
    transfer_sampling();
    collective_costs();
    shared_seed_derivations();
    pairing_comparison();
    streaming_overlap_comparison();
    boundary_idle_comparison();
    thousand_replica_scale();
}
