//! Topology + elastic-membership benchmarks: per-message transfer
//! sampling across the three network presets, payload-aware collective
//! cost models on a WAN, and the shared-seed derivations (live route
//! plans, churn masks) that sit on the trainers' hot path.
//!
//! `cargo bench --bench bench_topo`

use noloco::bench::{bench_row, section};
use noloco::collective::{
    pair_average_time_bytes, ring_all_reduce_time_bytes, tree_all_reduce_time_bytes,
    tree_all_reduce_time_over,
};
use noloco::config::{NetPreset, NetTopoConfig, Routing};
use noloco::net::topo::ChurnSchedule;
use noloco::net::SimClock;
use noloco::rngx::Pcg64;
use noloco::routing::RoutePlan;

fn transfer_sampling() {
    section("per-message transfer sampling (64 nodes, 4 MiB payload)");
    for preset in [
        NetPreset::SingleSwitchLan,
        NetPreset::MultiRegionWan,
        NetPreset::LongTailInternet,
    ] {
        let cfg = NetTopoConfig { preset, ..NetTopoConfig::default() };
        let topo = cfg.build(64, 1);
        let mut rng = Pcg64::seed_from_u64(2);
        bench_row(&format!("transfer_time, preset {preset}"), || {
            let mut acc = 0.0;
            for i in 0..64usize {
                acc += topo.transfer_time(i, (i * 7 + 1) % 64, 4 << 20, &mut rng);
            }
            std::hint::black_box(acc);
        });
    }
}

fn collective_costs() {
    section("payload-aware collective cost models (WAN, 4 MiB payload)");
    let wan = || {
        NetTopoConfig {
            preset: NetPreset::MultiRegionWan,
            regions: 4,
            ..NetTopoConfig::default()
        }
        .build(64, 1)
    };
    bench_row("tree all-reduce cost walk, n=64", || {
        let mut c = SimClock::with_topology(wan(), 3);
        std::hint::black_box(tree_all_reduce_time_bytes(&mut c, 4 << 20));
    });
    bench_row("ring all-reduce cost walk, n=64", || {
        let mut c = SimClock::with_topology(wan(), 4);
        std::hint::black_box(ring_all_reduce_time_bytes(&mut c, 4 << 20));
    });
    bench_row("gossip pair cost walk,    n=64", || {
        let mut c = SimClock::with_topology(wan(), 5);
        std::hint::black_box(pair_average_time_bytes(&mut c, None, 4 << 20));
    });
    bench_row("live-subset tree (48 of 64 live)", || {
        let mut c = SimClock::with_topology(wan(), 6);
        let live: Vec<usize> = (0..64).filter(|&w| w % 4 != 0).collect();
        std::hint::black_box(tree_all_reduce_time_over(&mut c, &live, 4 << 20));
    });
}

fn shared_seed_derivations() {
    section("shared-seed derivations on the trainer hot path");
    let live: Vec<usize> = (0..32).filter(|&r| r % 5 != 0).collect();
    bench_row("RoutePlan::for_step_over, dp=32 pp=4", || {
        let p = RoutePlan::for_step_over(Routing::Random, &live, 32, 4, 9, 1234);
        std::hint::black_box(p.boundaries());
    });
    let schedule = ChurnSchedule::none()
        .leave(10, 3)
        .leave(20, 7)
        .join(30, 3)
        .leave(40, 11)
        .join(50, 7);
    bench_row("ChurnSchedule::live_at, 5 events", || {
        for step in 0..64u64 {
            std::hint::black_box(schedule.live_at(32, step));
        }
    });
}

fn main() {
    println!("bench_topo — WAN topology, payload-aware collectives, elastic membership");
    transfer_sampling();
    collective_costs();
    shared_seed_derivations();
}
