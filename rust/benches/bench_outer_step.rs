//! Outer-optimizer benchmarks: the host-side reference implementations
//! (Eq. 2–3) across parameter counts, plus the fused XLA artifact when
//! artifacts are present — the actual hot path of the outer step.
//!
//! `cargo bench --bench bench_outer_step`

use noloco::bench::{bench_row, section};
use noloco::optim::{DilocoOuter, NolocoOuter, OuterState};
use noloco::rngx::Pcg64;
use noloco::runtime::{find_build, Engine};
use noloco::tensor::Tensor;
use noloco::train::outer_noloco;

fn host_side() {
    section("host-side outer optimizers (reference implementation)");
    let mut rng = Pcg64::seed_from_u64(3);
    for &n in &[1usize << 14, 1 << 18, 1 << 22] {
        let phi = vec![Tensor::randn(&[n], 0.1, &mut rng)];
        let theta = vec![Tensor::randn(&[n], 0.1, &mut rng)];
        let peer_phi = vec![Tensor::randn(&[n], 0.1, &mut rng)];

        let noloco = NolocoOuter { alpha: 0.5, beta: 0.7, gamma: 0.9 };
        let mut st = OuterState::new(&phi);
        let d = st.outer_grad(&theta);
        let pd = d.clone();
        bench_row(&format!("NoLoCo pair step (host), {n} params"), || {
            noloco.step_pair(&mut st, &theta, &d, &pd, &peer_phi);
        });

        let diloco = DilocoOuter { alpha: 0.3, beta: 0.7 };
        let mut st = OuterState::new(&phi);
        let mean = st.outer_grad(&theta);
        bench_row(&format!("DiLoCo step (host),      {n} params"), || {
            diloco.step(&mut st, &mean);
        });
    }
}

fn artifact_side() {
    let Ok(dir) = find_build("artifacts", "tiny", 2) else {
        println!("  (skipping artifact benches — run `make artifacts`)");
        return;
    };
    section("fused XLA outer-update artifact (the deployed hot path)");
    let mut eng = Engine::new(dir).expect("engine");
    let man = eng.manifest().unwrap();
    let n = man.param_count("first").unwrap();
    let mut rng = Pcg64::seed_from_u64(4);
    let mk = |rng: &mut Pcg64| -> Vec<f32> { (0..n).map(|_| rng.next_f32()).collect() };
    let mut phi = mk(&mut rng);
    let mut delta = mk(&mut rng);
    let dsum = mk(&mut rng);
    let psum = mk(&mut rng);
    // Warm the compile cache outside the timing loop.
    outer_noloco(
        &mut eng, noloco::model::StageKind::First, &mut phi, &mut delta, &dsum, &psum, 0.5,
        0.7, 0.9, 0.5,
    )
    .unwrap();
    bench_row(&format!("NoLoCo outer artifact, {n} params (tiny.first)"), || {
        outer_noloco(
            &mut eng,
            noloco::model::StageKind::First,
            &mut phi,
            &mut delta,
            &dsum,
            &psum,
            0.5,
            0.7,
            0.9,
            0.5,
        )
        .unwrap();
    });
}

fn main() {
    println!("bench_outer_step — Eq. 2-3 update throughput");
    host_side();
    artifact_side();
}
