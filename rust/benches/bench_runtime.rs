//! PJRT runtime benchmarks — per-artifact execution latency of every
//! function on the training hot path (fwd, bwd, adam, outer updates) plus
//! the end-to-end inner step, for the tiny and small builds.
//!
//! These are the numbers behind EXPERIMENTS.md §Perf: the coordinator's
//! own overhead (literal packing, routing, bookkeeping) must be small
//! against these execution times (≥90% of wall inside PJRT per DESIGN).
//!
//! `cargo bench --bench bench_runtime`  (requires `make artifacts`)

use noloco::bench::{bench, bench_row, format_row, section};
use noloco::config::presets;
use noloco::runtime::{find_build, lit_f32, lit_i32, Engine};
use noloco::train::{self, AdamScalars, SimTrainer};

fn tokens(n: usize, vocab: usize) -> Vec<i32> {
    (0..n).map(|i| ((i * 7919 + 13) % vocab) as i32).collect()
}

fn per_artifact(model: &str) {
    let Ok(dir) = find_build("artifacts", model, 2) else {
        println!("  (no {model}-pp2 artifacts)");
        return;
    };
    section(&format!("{model} build — per-artifact execution latency"));
    let mut eng = Engine::new(dir).unwrap();
    let man = eng.manifest().unwrap();
    let (mb, s, h, v) = (man.mb, man.seq_len, man.hidden, man.vocab);
    let n_first = man.param_count("first").unwrap();
    let n_last = man.param_count("last").unwrap();

    let first = train::init_stage(&mut eng, noloco::model::StageKind::First, 1).unwrap();
    let last = train::init_stage(&mut eng, noloco::model::StageKind::Last, 2).unwrap();
    let toks = tokens(mb * s, v);
    let hidden = train::fwd_first(&mut eng, &man, &first, &toks).unwrap();

    bench_row(&format!("first.fwd   ({n_first} params, {mb}x{s} toks)"), || {
        train::fwd_first(&mut eng, &man, &first, &toks).unwrap();
    });
    bench_row(&format!("last.bwd    ({n_last} params)"), || {
        train::bwd_last(&mut eng, &man, &last, &hidden, &toks).unwrap();
    });
    bench_row(&format!("first.bwd   ({n_first} params)"), || {
        train::bwd_first(&mut eng, &man, &first, &toks, &hidden).unwrap();
    });
    bench_row("last.loss   (validation path)", || {
        train::loss_last(&mut eng, &man, &last, &hidden, &toks).unwrap();
    });

    let mut flat = first.clone();
    let mut m = vec![0.0f32; n_first];
    let mut vv = vec![0.0f32; n_first];
    let g: Vec<f32> = first.iter().map(|x| x * 0.01).collect();
    bench_row(&format!("first.adam  ({n_first} params, fused clip+update)"), || {
        train::adam_step(
            &mut eng,
            noloco::model::StageKind::First,
            &mut flat,
            &mut m,
            &mut vv,
            &g,
            AdamScalars::at(1e-3, 1, 1.0),
        )
        .unwrap();
    });

    let mut phi = first.clone();
    let mut delta = vec![0.0f32; n_first];
    bench_row(&format!("first.outer_noloco ({n_first} params)"), || {
        train::outer_noloco(
            &mut eng,
            noloco::model::StageKind::First,
            &mut phi,
            &mut delta,
            &g,
            &first,
            0.5,
            0.7,
            0.9,
            0.5,
        )
        .unwrap();
    });

    // Literal packing overhead in isolation (coordinator-side cost).
    // §Perf: `lit_f32` was switched from vec1+reshape (two copies) to
    // create_from_shape_and_untyped_data (one copy); both are measured
    // here so the EXPERIMENTS.md before/after is regenerable.
    bench_row(&format!("literal pack/unpack, single-copy ({n_first} f32)"), || {
        let l = lit_f32(&first, &[n_first]).unwrap();
        std::hint::black_box(noloco::runtime::to_vec_f32(&l).unwrap());
    });
    bench_row(&format!("literal pack/unpack, vec1+reshape ({n_first} f32)"), || {
        let l = xla::Literal::vec1(&first).reshape(&[n_first as i64]).unwrap();
        std::hint::black_box(noloco::runtime::to_vec_f32(&l).unwrap());
    });
    let _ = lit_i32(&toks, &[mb, s]).unwrap();
    let _ = h;
}

fn end_to_end_step() {
    let Ok(dir) = find_build("artifacts", "tiny", 2) else { return };
    section("end-to-end inner step (tiny, dp=2 pp=2; Table-2 hot loop)");
    let mut eng = Engine::new(dir).unwrap();
    let mut cfg = presets::preset("tiny").unwrap();
    cfg.steps = 8;
    cfg.eval_every = 0;
    let mut trainer = SimTrainer::new(cfg, &mut eng).unwrap();
    let mut step = 0usize;
    // Warm: compile all artifacts.
    trainer.inner_step(step).unwrap();
    let s = bench(
        "SimTrainer::inner_step (route+fwd+bwd+adam, all workers)",
        std::time::Duration::from_millis(100),
        std::time::Duration::from_secs(3),
        || {
            step += 1;
            trainer.inner_step(step).unwrap();
        },
    );
    println!("{}", format_row(&s));
    println!(
        "  ({} XLA executions total across {} timed steps)",
        trainer.manifest().mb,
        s.iters_ns.len()
    );
}

fn main() {
    println!("bench_runtime — PJRT execution latency (EXPERIMENTS.md §Perf)");
    per_artifact("tiny");
    per_artifact("small");
    end_to_end_step();
}
