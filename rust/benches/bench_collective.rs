//! Collective benchmarks (Fig. 5A counterpart, measured): virtual-time
//! cost models across world sizes/σ, and *wall-clock* collectives over the
//! real in-process fabric across payload sizes.
//!
//! `cargo bench --bench bench_collective`

use noloco::bench::{bench_row, section};
use noloco::collective::{
    all_reduce_mean, pair_average_time, pair_exchange, reduce_scatter_gather,
    ring_all_reduce_time, tree_all_reduce_time,
};
use noloco::net::{Fabric, LatencyModel, SimClock};
use noloco::tensor::Tensor;

fn virtual_costs() {
    section("virtual-time cost models (Fig. 5A inputs)");
    for &sigma in &[0.125f64, 0.5, 1.0] {
        for &n in &[8usize, 64, 512] {
            let model = LatencyModel::LogNormal { mu: 0.0, sigma };
            let reps = 400;
            let (mut tree, mut ring, mut pair) = (0.0, 0.0, 0.0);
            for seed in 0..reps {
                let mut c = SimClock::new(n, model.clone(), seed);
                tree += tree_all_reduce_time(&mut c);
                let mut c = SimClock::new(n, model.clone(), seed + 5000);
                ring += ring_all_reduce_time(&mut c);
                let mut c = SimClock::new(n, model.clone(), seed + 9000);
                pair += pair_average_time(&mut c, None);
            }
            println!(
                "  n={n:<5} σ={sigma:<6} E[tree]={:<8.2} E[ring]={:<9.2} E[pair]={:<6.2} tree/pair={:.1}",
                tree / reps as f64,
                ring / reps as f64,
                pair / reps as f64,
                tree / pair
            );
        }
    }
}

fn wallclock_collectives() {
    section("wall-clock collectives over the fabric (4 ranks)");
    for &len in &[1usize << 10, 1 << 14, 1 << 18] {
        // Tree all-reduce.
        bench_row(&format!("tree all-reduce mean, {len} f32"), || {
            let mut fabric = Fabric::new(4);
            let eps = fabric.take_endpoints();
            let group: Vec<usize> = (0..4).collect();
            let handles: Vec<_> = eps
                .into_iter()
                .enumerate()
                .map(|(rank, mut ep)| {
                    let group = group.clone();
                    std::thread::spawn(move || {
                        let mut t = Tensor::full(&[len], rank as f32);
                        all_reduce_mean(&mut ep, &group, 0, &mut t);
                        t.as_slice()[0]
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
        });
        // Ring all-reduce.
        bench_row(&format!("ring all-reduce mean, {len} f32"), || {
            let mut fabric = Fabric::new(4);
            let eps = fabric.take_endpoints();
            let group: Vec<usize> = (0..4).collect();
            let handles: Vec<_> = eps
                .into_iter()
                .enumerate()
                .map(|(rank, mut ep)| {
                    let group = group.clone();
                    std::thread::spawn(move || {
                        let mut t = Tensor::full(&[len], rank as f32);
                        reduce_scatter_gather(&mut ep, &group, 0, &mut t);
                        t.as_slice()[0]
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
        });
        // Gossip pair exchange (the NoLoCo primitive).
        bench_row(&format!("gossip pair exchange,  {len} f32"), || {
            let mut fabric = Fabric::new(2);
            let eps = fabric.take_endpoints();
            let handles: Vec<_> = eps
                .into_iter()
                .enumerate()
                .map(|(rank, mut ep)| {
                    std::thread::spawn(move || {
                        let t = Tensor::full(&[len], rank as f32);
                        pair_exchange(&mut ep, 1 - rank, 0, &t).as_slice()[0]
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
        });
    }
}

fn main() {
    println!("bench_collective — tree/ring vs gossip (paper Fig. 5A / Table-2 comm columns)");
    virtual_costs();
    wallclock_collectives();
}
