//! Fig. 5B benchmark — the cost of *global blocking* in outer steps.
//!
//! Simulates training makespans under straggler-prone inner phases
//! (log-normal per-step latency, the paper's μ=1, σ²=0.5 setting):
//! DiLoCo barriers the whole world each outer round, NoLoCo only pairs.
//! Also measures the same effect in wall-clock on the real fabric with
//! latency injection.
//!
//! `cargo bench --bench bench_blocking`

use noloco::bench::{bench_row, section};
use noloco::collective::all_reduce_mean;
use noloco::net::Fabric;
use noloco::rngx::Pcg64;
use noloco::tensor::Tensor;

/// Simulated makespan ratio DiLoCo / NoLoCo (see examples/latency_analysis).
fn makespan_ratio(n: usize, m: usize, rounds: usize, seed: u64) -> f64 {
    let (mu, sigma) = (1.0, 0.5f64.sqrt());
    let mut rng = Pcg64::seed_from_u64(seed);
    let mut diloco = 0.0f64;
    let mut clocks = vec![0.0f64; n];
    for _ in 0..rounds {
        let phases: Vec<f64> = (0..n)
            .map(|_| (0..m).map(|_| rng.log_normal(mu, sigma)).sum::<f64>())
            .collect();
        diloco += phases.iter().cloned().fold(0.0, f64::max);
        for (a, b) in rng.random_pairs(n) {
            match b {
                Some(b) => {
                    let t = (clocks[a] + phases[a]).max(clocks[b] + phases[b]);
                    clocks[a] = t;
                    clocks[b] = t;
                }
                None => clocks[a] += phases[a],
            }
        }
    }
    diloco / clocks.iter().cloned().fold(0.0, f64::max)
}

fn main() {
    println!("bench_blocking — global barrier vs gossip pairing (Fig. 5B)");

    section("simulated makespan ratio DiLoCo/NoLoCo (250 outer rounds)");
    for &n in &[16usize, 64, 256, 1024] {
        for &m in &[25usize, 50, 100] {
            let r: f64 =
                (0..5).map(|s| makespan_ratio(n, m, 250, s)).sum::<f64>() / 5.0;
            println!("  n={n:<5} m={m:<4} ratio={r:.3}");
        }
    }

    section("wall-clock: barriered all-reduce vs gossip under latency injection");
    // 8 ranks, ~2 ms log-normal latency with fat tail.
    let (mu, sigma) = ((-6.5f64), 0.8f64); // ~1.5-2ms median
    for &world in &[4usize, 8] {
        bench_row(&format!("all-reduce barrier, {world} ranks, latency-injected"), || {
            let mut fabric = Fabric::new(world);
            let eps = fabric.take_endpoints();
            let group: Vec<usize> = (0..world).collect();
            let handles: Vec<_> = eps
                .into_iter()
                .enumerate()
                .map(|(rank, mut ep)| {
                    ep.set_latency_log_normal(mu, sigma);
                    let group = group.clone();
                    std::thread::spawn(move || {
                        let mut t = Tensor::full(&[256], rank as f32);
                        all_reduce_mean(&mut ep, &group, 0, &mut t);
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
        });
        bench_row(&format!("gossip pairs,       {world} ranks, latency-injected"), || {
            let mut fabric = Fabric::new(world);
            let eps = fabric.take_endpoints();
            let handles: Vec<_> = eps
                .into_iter()
                .enumerate()
                .map(|(rank, mut ep)| {
                    ep.set_latency_log_normal(mu, sigma);
                    std::thread::spawn(move || {
                        let peer = rank ^ 1; // disjoint pairs (2k, 2k+1)
                        let t = Tensor::full(&[256], rank as f32);
                        noloco::collective::pair_exchange(&mut ep, peer, 0, &t);
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
        });
    }
}
