//! Routing benchmarks: per-wave route-plan generation and path queries —
//! the L3 coordinator work that runs on every microbatch. DESIGN.md §Perf
//! target: O(DP) per boundary, microseconds at paper-scale topologies.
//!
//! `cargo bench --bench bench_routing`

use noloco::bench::{bench_row, section};
use noloco::config::Routing;
use noloco::routing::{pair_histogram, RoutePlan};

fn main() {
    println!("bench_routing — §3.1 dynamic pipeline routing");

    section("route-plan generation (one per microbatch wave)");
    for &(dp, pp) in &[(8usize, 2usize), (16, 4), (64, 8), (256, 8)] {
        let mut step = 0u64;
        bench_row(&format!("RoutePlan::random dp={dp} pp={pp}"), || {
            step += 1;
            let plan = RoutePlan::for_step(Routing::Random, dp, pp, 1, step);
            std::hint::black_box(plan.next_of(0, 0));
        });
    }
    for &(dp, pp) in &[(16usize, 4usize), (256, 8)] {
        bench_row(&format!("RoutePlan::fixed  dp={dp} pp={pp}"), || {
            let plan = RoutePlan::for_step(Routing::Fixed, dp, pp, 1, 1);
            std::hint::black_box(plan.next_of(0, 0));
        });
    }

    section("path queries on a built plan");
    let plan = RoutePlan::for_step(Routing::Random, 256, 8, 7, 9);
    bench_row("path_from (full 8-stage path, dp=256)", || {
        std::hint::black_box(plan.path_from(17));
    });
    let mut i = 0usize;
    bench_row("next_of/prev_of pair (one boundary)", || {
        i = (i + 1) % 256;
        let j = plan.next_of(3, i);
        std::hint::black_box(plan.prev_of(4, j));
    });

    section("pairing statistics (offline analysis helper)");
    bench_row("pair_histogram dp=16 pp=2 x100 steps", || {
        std::hint::black_box(pair_histogram(16, 2, 3, 100));
    });
}
