//! Cross-module integration tests that do not need PJRT artifacts:
//! routing × collectives × optimizers × quadratic theory × fabric faults.

use std::time::Duration;

use noloco::collective::{
    all_reduce_mean, pair_average_time, tree_all_reduce_time,
};
use noloco::config::{presets, Method, OuterConfig, Routing};
use noloco::net::{Fabric, FaultPlan, LatencyModel, Payload, SimClock, Tag};
use noloco::quad::{run_noloco, QuadSim, Quadratic};
use noloco::rngx::Pcg64;
use noloco::routing::{pair_histogram, RoutePlan};
use noloco::tensor::Tensor;

// ---------------------------------------------------------------------------
// Theorem 1 (§3.2, App. A): convergence + variance scaling on the quadratic
// ---------------------------------------------------------------------------

fn quad_sim(omega: f64, gamma: f64, outer_steps: usize) -> QuadSim {
    QuadSim {
        replicas: 8,
        inner_steps: 10,
        outer_steps,
        omega,
        outer: OuterConfig {
            method: Method::NoLoCo,
            alpha: 0.5,
            beta: 0.7,
            gamma,
            group: 2,
            inner_steps: 10,
            staleness: 1,
        },
        init_scale: 2.0,
    }
}

#[test]
fn theorem1_mean_converges_to_zero() {
    let mut rng = Pcg64::seed_from_u64(1);
    let problem = Quadratic::new(8, 0.2, 1.0, 0.4, &mut rng);
    let gamma = OuterConfig::default_gamma(0.5, 2);
    let res = run_noloco(&problem, &quad_sim(0.05, gamma, 200), 7);
    let early = res.mean_norm[5];
    let late = *res.mean_norm.last().unwrap();
    assert!(
        late < early * 0.05,
        "E(phi) must decay toward 0: early {early}, late {late}"
    );
}

#[test]
fn theorem1_variance_scales_with_omega_squared() {
    // V(phi) ∝ ω² at convergence: halving ω should quarter the variance
    // (within stochastic slack).
    let mut rng = Pcg64::seed_from_u64(2);
    let problem = Quadratic::new(8, 0.3, 1.0, 0.5, &mut rng);
    let gamma = OuterConfig::default_gamma(0.5, 2);
    let var_at = |omega: f64| {
        let res = run_noloco(&problem, &quad_sim(omega, gamma, 300), 11);
        let tail = &res.replica_var[250..];
        tail.iter().sum::<f64>() / tail.len() as f64
    };
    let v_hi = var_at(0.08);
    let v_lo = var_at(0.04);
    let ratio = v_hi / v_lo;
    assert!(
        (2.0..8.0).contains(&ratio),
        "variance ratio for 2x omega should be ~4, got {ratio:.2} ({v_hi:.3e} / {v_lo:.3e})"
    );
}

#[test]
fn gamma_outside_eq74_window_diverges_or_wobbles() {
    // γ below the window loses the consensus contraction: replica variance
    // must stay clearly above the in-window setting.
    let mut rng = Pcg64::seed_from_u64(3);
    let problem = Quadratic::new(6, 0.3, 1.0, 0.5, &mut rng);
    let run_var = |gamma: f64| {
        let res = run_noloco(&problem, &quad_sim(0.08, gamma, 150), 5);
        let tail = &res.replica_var[120..];
        tail.iter().sum::<f64>() / tail.len() as f64
    };
    let (lo, _hi) = OuterConfig::gamma_window(0.5, 2);
    let inside = run_var(OuterConfig::default_gamma(0.5, 2));
    let below = run_var(lo * 0.05); // nearly no consensus term
    assert!(
        below > inside * 1.5,
        "without consensus the ensemble should spread: inside {inside:.3e}, below {below:.3e}"
    );
}

// ---------------------------------------------------------------------------
// Routing (§3.1): permutations, retraced backward, load balance
// ---------------------------------------------------------------------------

#[test]
fn random_routing_is_balanced_and_retraceable() {
    let (dp, pp) = (8, 4);
    for step in 0..50u64 {
        let plan = RoutePlan::for_step(Routing::Random, dp, pp, 42, step);
        // Permutation property: every stage-s worker is on exactly one path.
        for s in 0..pp {
            let mut seen = vec![false; dp];
            for r in 0..dp {
                let p = plan.path_from(r);
                assert!(!seen[p[s]], "stage {s} replica reused");
                seen[p[s]] = true;
            }
        }
        // Backward retrace: prev_of inverts next_of at every boundary.
        for b in 0..plan.boundaries() {
            for i in 0..dp {
                let j = plan.next_of(b, i);
                assert_eq!(plan.prev_of(b + 1, j), i);
            }
        }
    }
}

#[test]
fn pair_histogram_is_roughly_uniform() {
    // Over many steps, stage-boundary pairings approach uniform — the
    // property that drives the implicit mixing of §5.2.
    let hist = pair_histogram(4, 2, 9, 4000);
    let total: u64 = hist.iter().flatten().sum();
    let cells = (hist.len() * hist[0].len()) as f64;
    let expect = total as f64 / cells;
    for row in &hist {
        for &c in row {
            let dev = (c as f64 - expect).abs() / expect;
            assert!(dev < 0.15, "cell {c} vs expected {expect}");
        }
    }
}

#[test]
fn fixed_routing_is_identity() {
    let plan = RoutePlan::for_step(Routing::Fixed, 4, 3, 1, 99);
    for r in 0..4 {
        assert_eq!(plan.path_from(r), vec![r, r, r]);
    }
}

// ---------------------------------------------------------------------------
// Collectives × fabric: numerics under faults, subgroups, latency costs
// ---------------------------------------------------------------------------

#[test]
fn gossip_survives_duplicated_messages() {
    // Tag-matched recv must be idempotent against duplicate delivery.
    let mut fabric = Fabric::with_faults(
        2,
        FaultPlan { drop_prob: 0.0, dup_prob: 0.5 },
        123,
    );
    let eps = fabric.take_endpoints();
    let handles: Vec<_> = eps
        .into_iter()
        .enumerate()
        .map(|(rank, mut ep)| {
            std::thread::spawn(move || {
                let mut acc = Vec::new();
                for step in 0..20u32 {
                    let mine = Tensor::from_slice(&[rank as f32 + step as f32]);
                    let theirs =
                        noloco::collective::pair_exchange(&mut ep, 1 - rank, step, &mine);
                    acc.push(theirs.as_slice()[0]);
                }
                acc
            })
        })
        .collect();
    let outs: Vec<Vec<f32>> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    for step in 0..20 {
        assert_eq!(outs[0][step], 1.0 + step as f32);
        assert_eq!(outs[1][step], step as f32);
    }
}

#[test]
fn dropped_message_detected_by_timeout() {
    let mut fabric = Fabric::with_faults(
        2,
        FaultPlan { drop_prob: 1.0, dup_prob: 0.0 },
        7,
    );
    let mut eps = fabric.take_endpoints();
    let mut e1 = eps.pop().unwrap();
    let mut e0 = eps.pop().unwrap();
    e0.send(1, Tag::new(9, 0, 0), Payload::Control);
    assert!(e1.recv_timeout(Tag::new(9, 0, 0), Duration::from_millis(50)).is_none());
}

#[test]
fn row_allreduce_in_grid_namespace() {
    // Two disjoint stage rows all-reduce concurrently with the same step
    // tag — point-to-point addressing must keep them independent.
    let (dp, pp) = (3, 2);
    let mut fabric = Fabric::new(dp * pp);
    let eps = fabric.take_endpoints();
    let handles: Vec<_> = eps
        .into_iter()
        .enumerate()
        .map(|(rank, mut ep)| {
            std::thread::spawn(move || {
                let stage = rank / dp;
                let row: Vec<usize> = (0..dp).map(|r| stage * dp + r).collect();
                let mut t = Tensor::from_slice(&[rank as f32]);
                all_reduce_mean(&mut ep, &row, 0, &mut t);
                t.as_slice()[0]
            })
        })
        .collect();
    let outs: Vec<f32> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    for rank in 0..dp * pp {
        let stage = rank / dp;
        let want: f32 =
            (0..dp).map(|r| (stage * dp + r) as f32).sum::<f32>() / dp as f32;
        assert!((outs[rank] - want).abs() < 1e-6, "rank {rank}");
    }
}

#[test]
fn tree_reduce_slower_than_gossip_on_simclock() {
    // Fig. 5A's qualitative claim, on the discrete-event simulator: the
    // tree all-reduce's expected time exceeds pair averaging, and the gap
    // grows with world size.
    let ratio_at = |n: usize| {
        let model = LatencyModel::LogNormal { mu: 0.0, sigma: 0.7 };
        let mut tree_total = 0.0;
        let mut pair_total = 0.0;
        for seed in 0..30 {
            let mut clock = SimClock::new(n, model.clone(), seed);
            tree_total += tree_all_reduce_time(&mut clock);
            let mut clock = SimClock::new(n, model.clone(), seed + 1000);
            pair_total += pair_average_time(&mut clock, None);
        }
        tree_total / pair_total
    };
    let r16 = ratio_at(16);
    let r128 = ratio_at(128);
    assert!(r16 > 1.5, "tree/gossip ratio at n=16: {r16}");
    assert!(r128 > r16, "ratio must grow with n: {r128} vs {r16}");
}

// ---------------------------------------------------------------------------
// Config system end-to-end
// ---------------------------------------------------------------------------

#[test]
fn preset_to_variants_round() {
    let base = presets::preset("small").unwrap();
    let d = presets::as_diloco(base.clone());
    let f = presets::as_fsdp(base.clone());
    assert_eq!(base.outer.method, Method::NoLoCo);
    assert_eq!(d.outer.method, Method::DiLoCo);
    assert_eq!(f.outer.method, Method::Fsdp);
    // All validate and keep the same model.
    for c in [&base, &d, &f] {
        c.validate().unwrap();
        assert_eq!(c.model.hidden, base.model.hidden);
    }
}

#[test]
fn gamma_default_sits_in_window_for_all_alphas() {
    for alpha in [0.0, 0.1, 0.3, 0.5, 0.7, 0.9] {
        for group in [2usize, 3, 4, 8] {
            let (lo, hi) = OuterConfig::gamma_window(alpha, group);
            let g = OuterConfig::default_gamma(alpha, group);
            assert!(lo < g && g < hi, "alpha {alpha} group {group}");
        }
    }
}
