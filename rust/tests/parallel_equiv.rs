//! Parallel-vs-serial bit-equality goldens for the execution pool.
//!
//! The `[perf] threads` knob fans the pp = 1 inner phase out over a
//! pool of worker threads, each with a private engine over the same
//! AOT artifact — and the contract is that this is a pure throughput
//! change: results are applied in exact submission order, so the
//! trajectory is **bit-identical** to the serial walk. These tests pin
//! that contract end-to-end on the grid executor (gated NoLoCo,
//! streaming fragments, bounded staleness > 1, FSDP, churn) and pin the
//! knob as inert on the threaded executor (each rank is already one
//! thread of a pool-of-ranks).
//!
//! Skips politely when the tiny pp = 1 artifact build is absent, like
//! every artifact-dependent suite (hardened by NOLOCO_REQUIRE_ARTIFACTS).

use noloco::config::{presets, Method, SyncMode, TrainConfig};
use noloco::net::ChurnSchedule;
use noloco::runtime::{find_build, Engine};
use noloco::train::{SimTrainer, ThreadedTrainer, TrainReport};

const ART: &str = "artifacts";

fn have_artifacts() -> bool {
    match find_build(ART, "tiny", 1) {
        Ok(_) => true,
        Err(e) => {
            if std::env::var_os("NOLOCO_REQUIRE_ARTIFACTS").is_some() {
                panic!("NOLOCO_REQUIRE_ARTIFACTS is set but tiny-pp1 is missing: {e}");
            }
            eprintln!("skipping: no tiny-pp1 artifacts; run `make artifacts` to enable");
            false
        }
    }
}

/// tiny preset at pp = 1, dp replicas × 2 microbatches, with the
/// requested pool width.
fn cfg(method: Method, dp: usize, steps: usize, threads: usize) -> TrainConfig {
    let base = presets::preset("tiny").unwrap();
    let mut cfg = match method {
        Method::Fsdp => presets::as_fsdp(base),
        Method::DiLoCo => presets::as_diloco(base),
        Method::NoLoCo => base,
    };
    cfg.topology.dp = dp;
    cfg.topology.pp = 1;
    cfg.steps = steps;
    cfg.warmup = 2;
    cfg.eval_every = 0;
    cfg.eval_tokens = 512;
    cfg.outer.inner_steps = 2;
    cfg.model.batch_tokens = dp * 2 * cfg.model.seq_len;
    cfg.perf.threads = threads;
    cfg
}

fn run_sim(cfg: TrainConfig, eng: &mut Engine) -> TrainReport {
    SimTrainer::new(cfg, eng).unwrap().run().unwrap()
}

/// The whole point of the pool's ordering contract: not "close", equal
/// to the bit — losses, comm accounting, trace and execution count.
fn assert_bit_identical(serial: &TrainReport, pooled: &TrainReport, what: &str) {
    assert_eq!(serial.step_train_loss, pooled.step_train_loss, "{what}: per-step loss bits");
    assert_eq!(serial.comm, pooled.comm, "{what}: CommStats");
    assert_eq!(serial.final_val_nll, pooled.final_val_nll, "{what}: final val NLL");
    assert_eq!(serial.trace.train_loss, pooled.trace.train_loss, "{what}: trace loss");
    assert_eq!(serial.trace.val_loss, pooled.trace.val_loss, "{what}: trace val");
    assert_eq!(serial.trace.weight_std, pooled.trace.weight_std, "{what}: trace σ");
    assert_eq!(serial.executions, pooled.executions, "{what}: PJRT execution count");
}

#[test]
fn pooled_gated_noloco_matches_serial_bits() {
    if !have_artifacts() {
        return;
    }
    let mut eng = Engine::new(find_build(ART, "tiny", 1).unwrap()).unwrap();
    let serial = run_sim(cfg(Method::NoLoCo, 4, 4, 1), &mut eng);
    for threads in [3, 0] {
        let pooled = run_sim(cfg(Method::NoLoCo, 4, 4, threads), &mut eng);
        assert_bit_identical(&serial, &pooled, &format!("gated noloco, threads={threads}"));
    }
    assert!(serial.step_train_loss.iter().all(|l| l.is_finite()));
}

#[test]
fn pooled_fsdp_matches_serial_bits() {
    // FSDP reads the gradient accumulators for its per-step all-reduce
    // before Adam drains them; the pooled Adam pass must not perturb
    // that ordering.
    if !have_artifacts() {
        return;
    }
    let mut eng = Engine::new(find_build(ART, "tiny", 1).unwrap()).unwrap();
    let serial = run_sim(cfg(Method::Fsdp, 4, 3, 1), &mut eng);
    let pooled = run_sim(cfg(Method::Fsdp, 4, 3, 3), &mut eng);
    assert_bit_identical(&serial, &pooled, "fsdp");
    assert_eq!(serial.comm.blocking_collectives, 3);
}

#[test]
fn pooled_streaming_fragments_match_serial_bits() {
    if !have_artifacts() {
        return;
    }
    let mut eng = Engine::new(find_build(ART, "tiny", 1).unwrap()).unwrap();
    let make = |threads| {
        let mut c = cfg(Method::NoLoCo, 4, 6, threads);
        c.sync = SyncMode::Streaming;
        c.stream.fragments = 2;
        c.stream.overlap = true;
        c
    };
    let serial = run_sim(make(1), &mut eng);
    let pooled = run_sim(make(3), &mut eng);
    assert_bit_identical(&serial, &pooled, "streaming fragments");
}

#[test]
fn pooled_async_staleness_matches_serial_bits() {
    if !have_artifacts() {
        return;
    }
    let mut eng = Engine::new(find_build(ART, "tiny", 1).unwrap()).unwrap();
    let make = |threads| {
        let mut c = cfg(Method::NoLoCo, 4, 6, threads);
        c.outer.staleness = 3;
        c
    };
    let serial = run_sim(make(1), &mut eng);
    let pooled = run_sim(make(3), &mut eng);
    assert_bit_identical(&serial, &pooled, "staleness 3");
}

#[test]
fn pooled_trains_through_churn_matches_serial() {
    // Replica 2 leaves at step 2 and rejoins at step 4: the pool must
    // reproduce the serial live-set walk (dead replicas submit no
    // tasks) and the donor-φ reseed bit-for-bit.
    if !have_artifacts() {
        return;
    }
    let mut eng = Engine::new(find_build(ART, "tiny", 1).unwrap()).unwrap();
    let make = |threads| {
        let mut c = cfg(Method::NoLoCo, 4, 6, threads);
        c.churn = ChurnSchedule::none().leave(2, 2).join(4, 2);
        c
    };
    let serial = run_sim(make(1), &mut eng);
    let pooled = run_sim(make(3), &mut eng);
    assert_bit_identical(&serial, &pooled, "churn");
}

#[test]
fn threads_knob_is_inert_on_threaded_executor() {
    // A threaded-executor rank is already one thread of a pool-of-ranks;
    // `[perf] threads` must not change its trajectory (or anything else).
    if !have_artifacts() {
        return;
    }
    let serial = ThreadedTrainer::new(cfg(Method::NoLoCo, 2, 4, 1))
        .with_val_batches(0)
        .run()
        .unwrap();
    let knobbed = ThreadedTrainer::new(cfg(Method::NoLoCo, 2, 4, 3))
        .with_val_batches(0)
        .run()
        .unwrap();
    assert_eq!(serial.step_train_loss, knobbed.step_train_loss);
    assert_eq!(serial.comm, knobbed.comm);
}
