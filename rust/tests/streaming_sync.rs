//! Streaming fragmented outer sync (`--sync streaming`) — overlap
//! semantics, cross-communicator determinism, and golden-trajectory
//! equivalence of the degenerate configuration.
//!
//! The communicator-level tests run without artifacts (host-side folds
//! need no engine); the trajectory tests drive the real trainers and
//! skip politely when the tiny artifact build is absent (hardened by
//! `NOLOCO_REQUIRE_ARTIFACTS`, as everywhere else).

use noloco::config::{presets, Method, StreamConfig, SyncMode, TrainConfig};
use noloco::model::StageKind;
use noloco::net::{ChurnSchedule, Fabric};
use noloco::runtime::{find_build, Engine};
use noloco::train::{
    strategy_for_config, AccountingComm, Communicator, FabricComm, SimTrainer, SyncStrategy,
    WorkerState,
};

const ART: &str = "artifacts";

fn streaming_cfg(fragments: usize, overlap: bool) -> TrainConfig {
    let mut cfg = presets::preset("tiny").unwrap();
    cfg.topology.dp = 2;
    cfg.topology.pp = 2;
    cfg.steps = 4;
    cfg.warmup = 2;
    cfg.eval_every = 2;
    cfg.eval_tokens = 512;
    cfg.outer.inner_steps = 2;
    cfg.sync = SyncMode::Streaming;
    cfg.stream = StreamConfig { fragments, overlap, ..StreamConfig::default() };
    cfg
}

fn have_artifacts(pp: usize) -> bool {
    match find_build(ART, "tiny", pp) {
        Ok(_) => true,
        Err(e) => {
            if std::env::var_os("NOLOCO_REQUIRE_ARTIFACTS").is_some() {
                panic!("NOLOCO_REQUIRE_ARTIFACTS is set but tiny-pp{pp} is missing: {e}");
            }
            eprintln!("skipping: no tiny-pp{pp} artifacts; run `make artifacts` to enable");
            false
        }
    }
}

fn worker(replica: usize, n: usize) -> WorkerState {
    // Deterministic, replica-distinct synthetic state: θ_i = f(i), φ = θ/2.
    let theta: Vec<f32> = (0..n)
        .map(|i| (i as f32 + 1.0) * if replica == 0 { 0.25 } else { -0.5 })
        .collect();
    let mut w = WorkerState::new(0, replica, StageKind::Full, theta, Method::NoLoCo);
    for p in w.phi.iter_mut() {
        *p *= 0.5;
    }
    w
}

/// Drive `boundaries` overlapped streaming rounds over one communicator
/// setup. `strategies[i]` serves `workers[i]`; the grid executor passes
/// the same strategy for both.
fn run_rounds(
    comms: &mut [&mut dyn Communicator],
    strategies: &mut [&mut dyn SyncStrategy],
    workers: &mut [WorkerState],
    boundaries: u64,
) {
    let live = vec![0usize, 1];
    for outer_idx in 1..=boundaries {
        // The core's boundary order: offers first (Δ snapshots), then
        // folds of the previous boundary's exchanges.
        for i in 0..workers.len() {
            strategies[i]
                .offer_outer(&mut *comms[i], &workers[i], &live, outer_idx)
                .unwrap();
        }
        for i in 0..workers.len() {
            strategies[i]
                .fold_inflight(&mut *comms[i], &mut workers[i], &live, outer_idx)
                .unwrap();
        }
        // A fake inner phase so the next boundary's Δ is non-trivial.
        for w in workers.iter_mut() {
            for x in w.theta.iter_mut() {
                *x += 0.1;
            }
        }
    }
    for i in 0..workers.len() {
        strategies[i]
            .drain(&mut *comms[i], &mut workers[i], &live, boundaries)
            .unwrap();
    }
}

/// Streamed folds must be bit-identical between the accounting mailbox
/// and real fabric messages: same offers, same collect order, same
/// host-side fragment math.
#[test]
fn streamed_folds_deterministic_across_communicators() {
    let n = 7;
    let mut cfg = streaming_cfg(3, true);
    cfg.topology.pp = 1;
    let phi0 = worker(0, n).phi.clone();

    // Grid-style: one strategy + one shared accounting communicator
    // serving both workers, in the core's boundary order.
    let mut acc = AccountingComm::new();
    let mut s = strategy_for_config(&cfg);
    let mut acc_workers = [worker(0, n), worker(1, n)];
    {
        let live = vec![0usize, 1];
        for outer_idx in 1..=4u64 {
            for w in acc_workers.iter() {
                s.offer_outer(&mut acc, w, &live, outer_idx).unwrap();
            }
            for w in acc_workers.iter_mut() {
                s.fold_inflight(&mut acc, w, &live, outer_idx).unwrap();
            }
            for w in acc_workers.iter_mut() {
                for x in w.theta.iter_mut() {
                    *x += 0.1;
                }
            }
        }
        for w in acc_workers.iter_mut() {
            s.drain(&mut acc, w, &live, 4).unwrap();
        }
    }

    // Threaded-style: one strategy + one fabric communicator per worker.
    let mut fabric = Fabric::new(2);
    let mut eps = fabric.take_endpoints().into_iter();
    let mut comm_a = FabricComm::new(eps.next().unwrap(), 2, None);
    let mut comm_b = FabricComm::new(eps.next().unwrap(), 2, None);
    let mut sa = strategy_for_config(&cfg);
    let mut sb = strategy_for_config(&cfg);
    let mut fab_workers = [worker(0, n), worker(1, n)];
    run_rounds(
        &mut [&mut comm_a, &mut comm_b],
        &mut [sa.as_mut(), sb.as_mut()],
        &mut fab_workers,
        4,
    );

    for (a, f) in acc_workers.iter().zip(&fab_workers) {
        assert_eq!(a.theta, f.theta, "θ diverged between communicators");
        assert_eq!(a.phi, f.phi, "φ diverged between communicators");
        assert_eq!(a.delta, f.delta, "δ diverged between communicators");
    }
    // The rounds actually folded something.
    assert_ne!(acc_workers[0].phi, phi0);
}

/// A fragment offered before a leave must be dropped at the next
/// boundary — on the fabric this also means *no blocking receive* from
/// the departed peer (the test would hang otherwise).
#[test]
fn stale_fragment_dropped_after_churn_leave() {
    let n = 6;
    let mut cfg = streaming_cfg(2, true);
    cfg.topology.pp = 1;
    let mut fabric = Fabric::new(2);
    let mut eps = fabric.take_endpoints().into_iter();
    let mut comm_a = FabricComm::new(eps.next().unwrap(), 2, None);
    let mut sa = strategy_for_config(&cfg);
    let mut w0 = worker(0, n);

    // Boundary 1: both replicas live; only worker 0's side runs here —
    // worker 1 "dies" before offering anything the fold could read.
    sa.offer_outer(&mut comm_a, &w0, &[0, 1], 1).unwrap();
    let phi_before = w0.phi.clone();
    // Boundary 2: replica 1 left; the in-flight fragment must be dropped
    // without touching state and without waiting on the dead peer.
    sa.fold_inflight(&mut comm_a, &mut w0, &[0], 2).unwrap();
    assert_eq!(w0.phi, phi_before, "stale fragment must not fold");
}

/// `fragments = 1` with overlap off routes through the gated strategy:
/// the loss trajectory, trace and comm accounting must be bit-identical
/// to `--sync gated` for both outer flavors.
#[test]
fn degenerate_streaming_matches_gated_golden_trajectories() {
    if !have_artifacts(2) {
        return;
    }
    let mut eng = Engine::new(find_build(ART, "tiny", 2).unwrap()).unwrap();
    for method in [Method::NoLoCo, Method::DiLoCo] {
        let mut gated = streaming_cfg(1, false);
        if method == Method::DiLoCo {
            gated = presets::as_diloco(gated);
            gated.outer.inner_steps = 2;
            gated.sync = SyncMode::Streaming; // as_diloco keeps it, but be explicit
        }
        let mut plain = gated.clone();
        plain.sync = SyncMode::Gated;
        let a = SimTrainer::new(plain, &mut eng).unwrap().run().unwrap();
        let b = SimTrainer::new(gated, &mut eng).unwrap().run().unwrap();
        assert_eq!(a.step_train_loss, b.step_train_loss, "{method}");
        assert_eq!(a.final_val_nll, b.final_val_nll, "{method}");
        assert_eq!(a.trace.train_loss, b.trace.train_loss, "{method}");
        assert_eq!(a.trace.val_loss, b.trace.val_loss, "{method}");
        assert_eq!(a.trace.weight_std, b.trace.weight_std, "{method}");
        assert_eq!(a.comm, b.comm, "{method}: comm accounting must not change");
    }
}

/// Overlapped streaming runs under both executors and follows the same
/// trajectory (host-side folds are executor-independent; the inner loop
/// matches to float tolerance as for the gated methods).
#[test]
fn streaming_overlap_runs_under_both_executors() {
    if !have_artifacts(2) {
        return;
    }
    let cfg = streaming_cfg(2, true);
    let sim = noloco::train::run_sim(&cfg).unwrap();
    assert_eq!(sim.executor, "sim");
    assert!(sim.step_train_loss.iter().all(|l| l.is_finite()));
    assert!(sim.final_val_nll.is_finite());
    assert_eq!(sim.comm.blocking_collectives, 0, "gossip flavor stays collective-free");
    assert!(sim.comm.pair_exchanges > 0);

    let thr = noloco::train::run_threaded(&cfg).unwrap();
    assert_eq!(thr.executor, "threaded");
    assert_eq!(thr.step_train_loss.len(), sim.step_train_loss.len());
    for (a, b) in thr.step_train_loss.iter().zip(&sim.step_train_loss) {
        assert!(
            (a - b).abs() < 1e-4,
            "threaded {a} vs sim {b} — streaming executors diverged"
        );
    }
    assert_eq!(thr.comm.pair_exchanges, sim.comm.pair_exchanges);
}

/// Streaming runs are deterministic: same seed, same trajectory, for
/// both the overlapped and the payload-split gated modes.
#[test]
fn streaming_trajectories_are_bit_stable() {
    if !have_artifacts(2) {
        return;
    }
    let mut eng = Engine::new(find_build(ART, "tiny", 2).unwrap()).unwrap();
    for (fragments, overlap) in [(2, true), (3, false), (1, true)] {
        let cfg = streaming_cfg(fragments, overlap);
        let a = SimTrainer::new(cfg.clone(), &mut eng).unwrap().run().unwrap();
        let b = SimTrainer::new(cfg, &mut eng).unwrap().run().unwrap();
        assert_eq!(a.step_train_loss, b.step_train_loss, "K={fragments} overlap={overlap}");
        assert_eq!(a.final_val_nll, b.final_val_nll, "K={fragments} overlap={overlap}");
        assert_eq!(a.comm, b.comm, "K={fragments} overlap={overlap}");
        assert!(a.step_train_loss.iter().all(|l| l.is_finite()));
    }
}

/// The threaded executor runs streaming NoLoCo through a leave + rejoin
/// too: in-flight fragments spanning the events are dropped, the
/// rejoiner catches up through the per-fragment adoption path (no grid
/// donor bootstrap on the fabric), and no fold ever blocks on a dead
/// peer.
#[test]
fn threaded_streaming_trains_through_leave_and_rejoin() {
    if !have_artifacts(2) {
        return;
    }
    let mut cfg = streaming_cfg(2, true);
    cfg.steps = 6;
    cfg.churn = ChurnSchedule::none().leave(2, 1).join(5, 1);
    let report = noloco::train::run_threaded(&cfg).unwrap();
    assert_eq!(report.step_train_loss.len(), 6);
    // Column 0 stayed live throughout, so every step mean is finite.
    assert!(report.step_train_loss.iter().all(|l| l.is_finite()));
    assert!(report.final_val_nll.is_finite());
    assert_eq!(report.comm.blocking_collectives, 0);
}

/// Streaming NoLoCo trains through a leave + rejoin: in-flight fragments
/// spanning the membership events are dropped and training completes.
#[test]
fn streaming_survives_churn() {
    if !have_artifacts(2) {
        return;
    }
    let mut eng = Engine::new(find_build(ART, "tiny", 2).unwrap()).unwrap();
    let mut cfg = streaming_cfg(2, true);
    cfg.steps = 6;
    cfg.churn = ChurnSchedule::none().leave(2, 1).join(5, 1);
    let mut t = SimTrainer::new(cfg, &mut eng).unwrap();
    let report = t.run().unwrap();
    assert!(report.final_val_nll.is_finite());
    assert!(t.is_live(1));
    assert!(t.worker(0, 1).theta.iter().all(|x| x.is_finite()));
    assert_eq!(report.comm.blocking_collectives, 0);
}
