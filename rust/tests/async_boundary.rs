//! Bounded-staleness async boundary engine + heartbeat failure
//! detection.
//!
//! Three layers of guarantees:
//!
//! * **Staleness property** (no artifacts): across random churn
//!   schedules, staleness windows and boundary counts, no fold ever
//!   admits peer state older than `outer.staleness − 1` boundaries.
//! * **Golden equivalence** (artifact-gated): with `staleness = 1` the
//!   config routes through the gated / streaming strategies untouched,
//!   and the rest of the boundary machinery (heartbeats, stash expiry,
//!   clocks) must not perturb those trajectories — bit-for-bit, on both
//!   executors.
//! * **Failure detection** (artifact-gated): a silenced replica is
//!   suspected after `churn.misses` missed heartbeats and repaired
//!   through the existing churn machinery — with *no* `ChurnSchedule`
//!   entry.

use noloco::config::{presets, Method, SyncMode, TrainConfig};
use noloco::model::StageKind;
use noloco::net::topo::ChurnEvent;
use noloco::net::ChurnSchedule;
use noloco::runtime::{find_build, Engine};
use noloco::train::{
    AccountingComm, AsyncGossipSync, BoundaryClock, SimTrainer, SyncStrategy, ThreadedTrainer,
    WorkerState,
};

const ART: &str = "artifacts";

fn have_artifacts(pp: usize) -> bool {
    match find_build(ART, "tiny", pp) {
        Ok(_) => true,
        Err(e) => {
            if std::env::var_os("NOLOCO_REQUIRE_ARTIFACTS").is_some() {
                panic!("NOLOCO_REQUIRE_ARTIFACTS is set but tiny-pp{pp} is missing: {e}");
            }
            eprintln!("skipping: no tiny-pp{pp} artifacts; run `make artifacts` to enable");
            false
        }
    }
}

fn base_cfg(dp: usize, pp: usize, steps: usize) -> TrainConfig {
    let mut cfg = presets::preset("tiny").unwrap();
    cfg.topology.dp = dp;
    cfg.topology.pp = pp;
    cfg.steps = steps;
    cfg.warmup = 2;
    cfg.eval_every = 0;
    cfg.eval_tokens = 512;
    cfg.outer.inner_steps = 2;
    cfg
}

// ---------------------------------------------------------------------
// Staleness property (no artifacts required)
// ---------------------------------------------------------------------

#[test]
fn property_no_fold_admits_state_older_than_staleness() {
    noloco::prop::run("bounded staleness admission", 60, |g| {
        let dp = g.usize_in(2, 5).max(2);
        let staleness = g.usize_in(1, 4).max(1);
        let boundaries = 1 + g.rng().next_u64() % 8;
        // Random churn over non-zero replicas: a leave and a rejoin at
        // random steps (inner_steps = 1, so steps are boundaries).
        let mut churn = ChurnSchedule::none();
        for _ in 0..g.usize_in(0, 2) {
            let node = 1 + (g.rng().next_u64() as usize) % (dp - 1).max(1);
            let at = g.rng().next_u64() % boundaries.max(1);
            churn = churn.leave(at, node);
            churn = churn.join(at + 1 + g.rng().next_u64() % 4, node);
        }
        let mut cfg = presets::preset("tiny").unwrap();
        cfg.topology.dp = dp;
        cfg.outer.inner_steps = 1;
        cfg.outer.staleness = staleness;
        cfg.churn = churn.clone();
        let mut s = AsyncGossipSync::from_config(&cfg);
        let mut comm = AccountingComm::new();
        let mut workers: Vec<WorkerState> = (0..dp)
            .map(|r| {
                let theta: Vec<f32> = (0..6).map(|i| (i + r + 1) as f32 * 0.25).collect();
                let mut w =
                    WorkerState::new(0, r, StageKind::Full, theta, Method::NoLoCo);
                for p in w.phi.iter_mut() {
                    *p *= 0.5;
                }
                w
            })
            .collect();
        let clock = BoundaryClock::new(churn, dp, 1);
        for b in 1..=boundaries {
            let live: Vec<usize> =
                (0..dp).filter(|&r| clock.live_at_boundary(r, b)).collect();
            if live.len() < 2 {
                continue;
            }
            for &r in &live {
                s.offer_outer(&mut comm, &workers[r], &live, b).unwrap();
            }
            for &r in &live {
                s.fold_boundary(&mut comm, &mut workers[r], &live, b).unwrap();
            }
        }
        assert!(
            s.max_admitted_age() < staleness as u64,
            "fold admitted age {} under staleness {staleness}",
            s.max_admitted_age()
        );
        for w in &workers {
            assert!(w.phi.iter().all(|x| x.is_finite()));
        }
    });
}

#[test]
fn clock_lag_equals_missed_boundaries() {
    // Cross-check the two clock derivations on a nontrivial schedule.
    let churn = ChurnSchedule::none().leave(3, 1).join(8, 1).leave(10, 2);
    let clock = BoundaryClock::new(churn, 3, 2);
    // Boundary b closes step 2b - 1: replica 1 dead over steps 3..7
    // misses boundaries 2 (step 3), 3 (step 5), 4 (step 7); replica 2
    // dead from step 10 misses boundary 6 (step 11) on.
    for b in 1..=6u64 {
        assert_eq!(clock.clock_of(0, b), b);
    }
    assert_eq!(clock.clock_of(1, 6), 3);
    assert_eq!(clock.clock_of(2, 6), 5);
}

// ---------------------------------------------------------------------
// Golden equivalence: staleness = 1 + boundary machinery ≡ the gated /
// streaming trajectories (artifact-gated)
// ---------------------------------------------------------------------

/// Bitwise comparison of per-step losses (NaN-tolerant: both NaN is
/// equal — a churned step nobody reported).
fn assert_same_losses(a: &[f64], b: &[f64], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(
            (x.is_nan() && y.is_nan()) || x.to_bits() == y.to_bits(),
            "{what}: step {i} diverged: {x} vs {y}"
        );
    }
}

/// The machinery knobs the async engine added, applied to a lockstep
/// run: explicit staleness 1, the stash-expiry sweep, and heartbeat
/// detection with nothing failing. None of it may touch the trajectory.
fn with_boundary_machinery(mut cfg: TrainConfig) -> TrainConfig {
    cfg.outer.staleness = 1;
    cfg.stream.stash_age = 4;
    cfg.detect.enabled = true;
    cfg.detect.misses = 2;
    cfg
}

#[test]
fn staleness_one_reproduces_the_gated_trajectory_on_the_grid() {
    if !have_artifacts(2) {
        return;
    }
    let cfg = base_cfg(2, 2, 6);
    let mut base = cfg.clone();
    base.stream.stash_age = 0; // the pre-expiry behaviour
    let dir = find_build(ART, "tiny", 2).unwrap();
    let mut eng = Engine::new(&dir).unwrap();
    let mut t = SimTrainer::new(base, &mut eng).unwrap();
    let r0 = t.run().unwrap();
    let phi0 = t.worker(0, 0).phi.clone();
    let theta0 = t.worker(1, 1).theta.clone();

    let mut eng = Engine::new(&dir).unwrap();
    let mut t = SimTrainer::new(with_boundary_machinery(cfg), &mut eng).unwrap();
    let r1 = t.run().unwrap();
    assert_same_losses(&r0.step_train_loss, &r1.step_train_loss, "gated vs staleness-1");
    assert_eq!(phi0, t.worker(0, 0).phi);
    assert_eq!(theta0, t.worker(1, 1).theta);
    assert!(r1.detected.is_empty(), "nothing failed, nothing may be detected");
}

#[test]
fn staleness_one_reproduces_the_streaming_trajectory_on_the_grid() {
    if !have_artifacts(2) {
        return;
    }
    let mut cfg = base_cfg(2, 2, 6);
    cfg.sync = SyncMode::Streaming;
    cfg.stream.fragments = 2;
    cfg.stream.overlap = true;
    let mut base = cfg.clone();
    base.stream.stash_age = 0;
    let dir = find_build(ART, "tiny", 2).unwrap();
    let mut eng = Engine::new(&dir).unwrap();
    let r0 = SimTrainer::new(base, &mut eng).unwrap().run().unwrap();

    let mut eng = Engine::new(&dir).unwrap();
    let r1 = SimTrainer::new(with_boundary_machinery(cfg), &mut eng)
        .unwrap()
        .run()
        .unwrap();
    assert_same_losses(&r0.step_train_loss, &r1.step_train_loss, "streaming vs staleness-1");
    assert_eq!(r0.final_val_nll.to_bits(), r1.final_val_nll.to_bits());
}

#[test]
fn staleness_one_reproduces_the_gated_trajectory_on_the_fabric() {
    if !have_artifacts(2) {
        return;
    }
    let cfg = base_cfg(2, 2, 6);
    let mut base = cfg.clone();
    base.stream.stash_age = 0;
    let r0 = ThreadedTrainer::new(base).run().unwrap();
    let r1 = ThreadedTrainer::new(with_boundary_machinery(cfg)).run().unwrap();
    assert_same_losses(&r0.step_train_loss, &r1.step_train_loss, "threaded gated vs staleness-1");
    assert_eq!(r0.final_val_nll.to_bits(), r1.final_val_nll.to_bits());
    assert!(r1.detected.is_empty());
}

// ---------------------------------------------------------------------
// The async engine end-to-end (artifact-gated)
// ---------------------------------------------------------------------

#[test]
fn async_engine_matches_across_executors_without_churn() {
    // Churn-free: every age is 0, the weighted fold is the uniform group
    // mean, and the two executors must follow the same trajectory (the
    // train_modes float tolerance: separate PJRT engines, same
    // algorithm).
    if !have_artifacts(2) {
        return;
    }
    let mut cfg = base_cfg(2, 2, 6);
    cfg.outer.staleness = 3;
    let dir = find_build(ART, "tiny", 2).unwrap();
    let mut eng = Engine::new(&dir).unwrap();
    let mut t = SimTrainer::new(cfg.clone(), &mut eng).unwrap();
    let report = t.run().unwrap();
    assert!(report.final_val_nll.is_finite());
    assert_eq!(t.boundary_clocks(), &[3, 3]);
    let r2 = ThreadedTrainer::new(cfg).run().unwrap();
    assert_eq!(report.step_train_loss.len(), r2.step_train_loss.len());
    for (i, (a, b)) in report.step_train_loss.iter().zip(&r2.step_train_loss).enumerate() {
        assert!(
            (a - b).abs() < 1e-4,
            "sim vs threaded async diverged at step {i}: {a} vs {b}"
        );
    }
}

#[test]
fn async_engine_trains_through_churn_and_lags_the_clock() {
    if !have_artifacts(2) {
        return;
    }
    let mut cfg = base_cfg(2, 2, 12);
    cfg.outer.staleness = 3;
    // Replica 1 dead over steps 2..5: misses the boundaries closing at
    // steps 3 and 5 (boundaries 2 and 3 of 6).
    cfg.churn = ChurnSchedule::none().leave(2, 1).join(6, 1);
    let dir = find_build(ART, "tiny", 2).unwrap();
    let mut eng = Engine::new(&dir).unwrap();
    let mut t = SimTrainer::new(cfg.clone(), &mut eng).unwrap();
    let report = t.run().unwrap();
    assert!(report.final_val_nll.is_finite());
    assert_eq!(t.boundary_clocks(), &[6, 4], "replica 1 missed two boundaries");
    // The core's clocks agree with the schedule-derived engine clocks.
    let clock = BoundaryClock::new(cfg.churn.clone(), 2, cfg.outer.inner_steps);
    assert_eq!(clock.clock_of(0, 6), 6);
    assert_eq!(clock.clock_of(1, 6), 4);
    // The fabric run repairs through adoption (grid reseeds at the join
    // instead — the same executor asymmetry as the gated strategy), so
    // trajectories are not compared; it must complete and train.
    let r2 = ThreadedTrainer::new(cfg).run().unwrap();
    assert!(r2.final_val_nll.is_finite());
    assert!(r2
        .step_train_loss
        .iter()
        .all(|l| l.is_finite() || l.is_nan()));
}

// ---------------------------------------------------------------------
// Failure detection without a schedule (artifact-gated)
// ---------------------------------------------------------------------

#[test]
fn silenced_replica_is_suspected_and_repaired_without_a_schedule() {
    if !have_artifacts(2) {
        return;
    }
    let mut cfg = base_cfg(2, 2, 12);
    cfg.detect.enabled = true;
    cfg.detect.misses = 2;
    assert!(cfg.churn.is_empty(), "the whole point: no schedule entry");
    let dir = find_build(ART, "tiny", 2).unwrap();
    let mut eng = Engine::new(&dir).unwrap();
    // Boundary b closes step 2b - 1; silencing steps [4, 10) suppresses
    // the heartbeats of boundaries 3, 4, 5 and resumes at boundary 6.
    let mut t = SimTrainer::new(cfg, &mut eng)
        .unwrap()
        .with_silence(1, 4, 10);
    let report = t.run().unwrap();
    assert_eq!(
        report.detected,
        vec![(4, ChurnEvent::Leave(1)), (6, ChurnEvent::Join(1))],
        "suspect after 2 missed heartbeats, re-admit on resume"
    );
    assert!(report.final_val_nll.is_finite());
    // The rejoin reused the donor-bootstrap repair: the run finished with
    // both replicas live and training (finite losses on the tail steps).
    assert!(report.step_train_loss.iter().all(|l| l.is_finite()));
    assert!(t.is_live(1));
}

#[test]
fn threaded_crash_is_detected_and_survivor_finishes() {
    if !have_artifacts(1) {
        return;
    }
    let mut cfg = base_cfg(2, 1, 12);
    cfg.detect.enabled = true;
    cfg.detect.misses = 2;
    let report = ThreadedTrainer::new(cfg)
        .with_gossip_timeout(std::time::Duration::from_millis(100))
        .with_silence(1, 4)
        .run()
        .unwrap();
    assert!(
        report
            .detected
            .iter()
            .any(|&(_, e)| e == ChurnEvent::Leave(1)),
        "the survivor must detect the crash: {:?}",
        report.detected
    );
    assert!(report.final_val_nll.is_finite(), "the survivor still trains and evals");
    assert_eq!(report.executor, "threaded");
}
