//! Training-mode integration tests over the real PJRT stack.
//!
//! These exercise [`noloco::train::SimTrainer`] / [`ThreadedTrainer`] with
//! the tiny artifact build (skipping politely when artifacts are absent)
//! and pin the *algorithmic* invariants of the three methods:
//!
//! * FSDP keeps replicas bit-identical (all-reduced grads + shared init);
//! * DiLoCo leaves θ = φ right after an outer step;
//! * NoLoCo replicas diverge between outer steps but remain finite and
//!   the γ-term keeps them clustered;
//! * the sim and threaded executors follow the same trajectory for FSDP.

use noloco::cli::{train_config_from, Args};
use noloco::config::{presets, Method, Routing, TrainConfig};
use noloco::net::ChurnSchedule;
use noloco::runtime::{find_build, Engine};
use noloco::train::{SimTrainer, ThreadedTrainer};

const ART: &str = "artifacts";

fn cfg_for(method: Method, dp: usize, pp: usize, steps: usize) -> TrainConfig {
    let base = presets::preset("tiny").unwrap();
    let mut cfg = match method {
        Method::Fsdp => presets::as_fsdp(base),
        Method::DiLoCo => presets::as_diloco(base),
        Method::NoLoCo => base,
    };
    cfg.topology.dp = dp;
    cfg.topology.pp = pp;
    cfg.steps = steps;
    cfg.warmup = 2;
    cfg.eval_every = 0;
    cfg.eval_tokens = 512;
    if method == Method::DiLoCo {
        cfg.outer.inner_steps = 4;
    }
    if method == Method::NoLoCo {
        cfg.outer.inner_steps = 2;
    }
    cfg
}

/// Whether the tiny artifact build for `pp` stages exists. When it does
/// not, artifact-dependent tests skip cleanly — unless
/// `NOLOCO_REQUIRE_ARTIFACTS` is set (CI images that ran `make
/// artifacts`), in which case a missing build is a hard failure instead
/// of a silent skip.
fn have_artifacts(pp: usize) -> bool {
    match find_build(ART, "tiny", pp) {
        Ok(_) => true,
        Err(e) => {
            if std::env::var_os("NOLOCO_REQUIRE_ARTIFACTS").is_some() {
                panic!("NOLOCO_REQUIRE_ARTIFACTS is set but tiny-pp{pp} is missing: {e}");
            }
            eprintln!("skipping: no tiny-pp{pp} artifacts; run `make artifacts` to enable");
            false
        }
    }
}

fn engine(pp: usize) -> Option<Engine> {
    if !have_artifacts(pp) {
        return None;
    }
    Some(Engine::new(find_build(ART, "tiny", pp).unwrap()).unwrap())
}

#[test]
fn fsdp_replicas_stay_bit_identical() {
    let Some(mut eng) = engine(2) else { return };
    let cfg = cfg_for(Method::Fsdp, 2, 2, 3);
    let mut t = SimTrainer::new(cfg, &mut eng).unwrap();
    let report = t.run().unwrap();
    assert!(report.final_val_nll.is_finite());
    // All-reduced grads + identical init => identical replicas, σ == 0.
    assert!(
        t.weight_std() < 1e-7,
        "FSDP weight σ must be ~0, got {}",
        t.weight_std()
    );
    for s in 0..2 {
        assert_eq!(t.worker(s, 0).theta, t.worker(s, 1).theta, "stage {s}");
    }
    // FSDP blocks on a collective every step for every stage row.
    assert_eq!(report.comm.blocking_collectives, 3 * 2);
    assert_eq!(report.comm.pair_exchanges, 0);
}

#[test]
fn noloco_diverges_between_syncs_but_stays_clustered() {
    let Some(mut eng) = engine(2) else { return };
    // Outer steps at 2 and 4; step 5 runs inner-only so replicas have
    // diverged again when we measure. (At dp = 2 the gossip pair covers
    // the whole world, so σ collapses to ~0 *at* an outer step — the
    // n = N degenerate case the paper notes below Eq. 2.)
    let cfg = cfg_for(Method::NoLoCo, 2, 2, 5);
    let mut t = SimTrainer::new(cfg, &mut eng).unwrap();
    let report = t.run().unwrap();
    assert!(report.final_val_nll.is_finite());
    // Replicas see different data shards and never all-reduce: σ > 0.
    let sigma = t.weight_std();
    assert!(sigma > 0.0, "NoLoCo replicas should differ");
    assert!(sigma < 1.0, "…but stay clustered (σ = {sigma})");
    // Gossip pairs, no collectives.
    assert_eq!(report.comm.blocking_collectives, 0);
    assert_eq!(report.comm.pair_exchanges, 2 * 2); // 2 outer steps x 2 stages x 1 pair
    // θ and φ differ mid-inner-phase (θ has taken an Adam step since).
    assert_ne!(t.worker(0, 0).theta, t.worker(0, 0).phi);
}

#[test]
fn diloco_outer_resets_theta_to_phi_and_uses_collectives() {
    let Some(mut eng) = engine(2) else { return };
    let cfg = cfg_for(Method::DiLoCo, 2, 2, 4); // outer at step 4
    let mut t = SimTrainer::new(cfg, &mut eng).unwrap();
    let report = t.run().unwrap();
    assert!(report.final_val_nll.is_finite());
    for s in 0..2 {
        for r in 0..2 {
            assert_eq!(t.worker(s, r).theta, t.worker(s, r).phi);
        }
    }
    // One outer all-reduce per stage row; no gossip.
    assert_eq!(report.comm.blocking_collectives, 2);
    assert_eq!(report.comm.pair_exchanges, 0);
    // DiLoCo's outer all-reduce keeps φ identical across replicas (all
    // see the same mean Δ and share φ₀).
    for s in 0..2 {
        assert_eq!(t.worker(s, 0).phi, t.worker(s, 1).phi, "stage {s}");
    }
}

#[test]
fn pp1_full_stage_trains() {
    let Some(mut eng) = engine(1) else { return };
    let mut cfg = cfg_for(Method::NoLoCo, 2, 1, 4);
    cfg.outer.inner_steps = 2;
    let mut t = SimTrainer::new(cfg, &mut eng).unwrap();
    let report = t.run().unwrap();
    assert!(report.final_val_nll.is_finite());
    assert!(report.final_val_ppl > 1.0);
}

#[test]
fn same_seed_same_trajectory() {
    let Some(mut eng) = engine(2) else { return };
    let cfg = cfg_for(Method::NoLoCo, 2, 2, 3);
    let a = SimTrainer::new(cfg.clone(), &mut eng).unwrap().run().unwrap();
    let b = SimTrainer::new(cfg, &mut eng).unwrap().run().unwrap();
    assert_eq!(a.final_val_nll, b.final_val_nll);
    assert_eq!(a.comm, b.comm);
}

#[test]
fn different_seed_different_trajectory() {
    let Some(mut eng) = engine(2) else { return };
    let mut cfg = cfg_for(Method::NoLoCo, 2, 2, 3);
    let a = SimTrainer::new(cfg.clone(), &mut eng).unwrap().run().unwrap();
    cfg.seed ^= 1;
    let b = SimTrainer::new(cfg, &mut eng).unwrap().run().unwrap();
    assert_ne!(a.final_val_nll, b.final_val_nll);
}

#[test]
fn fixed_routing_isolates_pipelines() {
    // With fixed routing + no outer sync (inner_steps > steps), replicas
    // never exchange information: σ must exceed zero and the random
    // variant must stay in the same band (the Fig. 4A effect needs longer
    // runs; here we pin the mechanics).
    let Some(mut eng) = engine(2) else { return };
    let mut cfg = cfg_for(Method::NoLoCo, 2, 2, 4);
    cfg.outer.inner_steps = 1000; // no outer step within the run
    cfg.routing = Routing::Fixed;
    let mut t_fixed = SimTrainer::new(cfg.clone(), &mut eng).unwrap();
    t_fixed.run().unwrap();
    let sigma_fixed = t_fixed.weight_std();

    cfg.routing = Routing::Random;
    let mut t_rand = SimTrainer::new(cfg, &mut eng).unwrap();
    t_rand.run().unwrap();
    let sigma_rand = t_rand.weight_std();

    assert!(sigma_fixed > 0.0 && sigma_rand > 0.0);
    assert!(
        sigma_rand < sigma_fixed * 1.5,
        "random routing should not blow up divergence: {sigma_rand} vs {sigma_fixed}"
    );
}

#[test]
fn checkpoint_roundtrip_through_trainer() {
    let Some(mut eng) = engine(2) else { return };
    let cfg = cfg_for(Method::NoLoCo, 2, 2, 2);
    let (ck, trained): (_, Vec<(Vec<f32>, Vec<f32>)>) = {
        let mut t = SimTrainer::new(cfg.clone(), &mut eng).unwrap();
        t.run().unwrap();
        let trained = (0..2)
            .flat_map(|s| (0..2).map(move |r| (s, r)))
            .map(|(s, r)| (t.worker(s, r).theta.clone(), t.worker(s, r).phi.clone()))
            .collect();
        (t.checkpoint(2), trained)
    };
    let path = std::env::temp_dir().join("noloco_train_ck.bin");
    ck.save(&path).unwrap();
    let loaded = noloco::train::Checkpoint::load(&path).unwrap();
    let mut fresh = SimTrainer::new(cfg, &mut eng).unwrap();
    assert_ne!(fresh.worker(0, 0).theta, trained[0].0);
    let step = fresh.restore(&loaded).unwrap();
    assert_eq!(step, 2);
    for s in 0..2 {
        for r in 0..2 {
            let (theta, phi) = &trained[s * 2 + r];
            assert_eq!(&fresh.worker(s, r).theta, theta);
            assert_eq!(&fresh.worker(s, r).phi, phi);
        }
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn threaded_fsdp_matches_sim_trajectory() {
    // The two executors implement the same algorithm; for FSDP (fully
    // deterministic synchronization) their loss series must agree to
    // float tolerance.
    if !have_artifacts(2) {
        return;
    }
    let cfg = cfg_for(Method::Fsdp, 2, 2, 2);

    let mut eng = engine(2).unwrap();
    let mut sim = SimTrainer::new(cfg.clone(), &mut eng).unwrap();
    let mut sim_losses = Vec::new();
    for step in 0..cfg.steps {
        sim_losses.push(sim.inner_step(step).unwrap());
    }

    let threaded = ThreadedTrainer::new(cfg).with_val_batches(0).run().unwrap();
    assert_eq!(threaded.step_train_loss.len(), sim_losses.len());
    for (a, b) in threaded.step_train_loss.iter().zip(&sim_losses) {
        assert!(
            (a - b).abs() < 1e-4,
            "threaded {a} vs sim {b} — executors diverged"
        );
    }
}

#[test]
fn threaded_noloco_runs_and_reports() {
    if !have_artifacts(2) {
        return;
    }
    let cfg = cfg_for(Method::NoLoCo, 2, 2, 2);
    let report = ThreadedTrainer::new(cfg).with_val_batches(2).run().unwrap();
    assert_eq!(report.executor, "threaded");
    assert_eq!(report.step_train_loss.len(), 2);
    assert!(report.step_train_loss.iter().all(|l| l.is_finite()));
    assert!(report.final_val_nll.is_finite());
    assert!(report.comm.bytes_sent > 0);
    assert!(report.comm.msgs_sent > 0);
    // One outer step over 2 stages at dp = 2: a pair per stage row, and
    // no blocking collectives — the unified counters keep seed semantics.
    assert_eq!(report.comm.pair_exchanges, 2);
    assert_eq!(report.comm.blocking_collectives, 0);
    assert!(report.comm.activation_hops > 0);
}

#[test]
fn threaded_noloco_survives_straggling_gossip_peers() {
    // Straggler tolerance: with injected latency far above the gossip
    // timeout every exchange falls back to a singleton update — training
    // must still complete with finite losses. (A DiLoCo collective would
    // simply stall; there is nothing to skip.)
    if !have_artifacts(2) {
        return;
    }
    let cfg = cfg_for(Method::NoLoCo, 2, 2, 2);
    let report = ThreadedTrainer::new(cfg)
        .with_val_batches(0)
        .with_latency(-4.0, 0.3) // ~18 ms median per message
        .with_gossip_timeout(std::time::Duration::from_millis(1))
        .run()
        .unwrap();
    assert_eq!(report.step_train_loss.len(), 2);
    assert!(report.step_train_loss.iter().all(|l| l.is_finite()));
}

#[test]
fn threaded_rejects_churn_for_global_methods() {
    // Needs no artifacts: the membership check fires before artifact
    // resolution — DiLoCo's all-reduce has no live-subset form.
    let mut cfg = cfg_for(Method::DiLoCo, 2, 2, 4);
    cfg.churn = ChurnSchedule::none().leave(2, 1);
    let err = ThreadedTrainer::new(cfg).run().unwrap_err();
    assert!(err.to_string().contains("membership"), "{err}");
}

#[test]
fn sim_global_methods_abort_on_churn() {
    let Some(mut eng) = engine(2) else { return };
    let mut cfg = cfg_for(Method::DiLoCo, 2, 2, 4);
    cfg.churn = ChurnSchedule::none().leave(2, 1);
    let err = SimTrainer::new(cfg, &mut eng).unwrap().run().unwrap_err();
    assert!(err.to_string().contains("membership"), "{err}");
}

#[test]
fn sim_noloco_trains_through_leave_and_rejoin() {
    // Replica 1 drops at step 2 and rejoins at step 5 (mid outer round,
    // so it re-enters via the donor-φ bootstrap). Training completes and
    // the rejoined replica is live and finite.
    let Some(mut eng) = engine(2) else { return };
    let mut cfg = cfg_for(Method::NoLoCo, 2, 2, 6);
    cfg.churn = ChurnSchedule::none().leave(2, 1).join(5, 1);
    let mut t = SimTrainer::new(cfg, &mut eng).unwrap();
    let report = t.run().unwrap();
    assert!(report.final_val_nll.is_finite());
    assert!(t.is_live(1));
    assert_eq!(t.live_replicas(), vec![0, 1]);
    assert!(t.worker(0, 1).theta.iter().all(|x| x.is_finite()));
    // Gossip ran on every boundary (some as singletons) — no collectives.
    assert_eq!(report.comm.blocking_collectives, 0);
}

#[test]
fn threaded_noloco_trains_through_leave_and_rejoin() {
    // The threaded executor derives the same live sets from the shared
    // schedule: column 1 sits out steps 2–4, rejoins at 5 and catches up
    // by absorbing its first gossip peer's slow weights.
    if !have_artifacts(2) {
        return;
    }
    let mut cfg = cfg_for(Method::NoLoCo, 2, 2, 6);
    cfg.churn = ChurnSchedule::none().leave(2, 1).join(5, 1);
    let report = ThreadedTrainer::new(cfg).with_val_batches(2).run().unwrap();
    assert_eq!(report.step_train_loss.len(), 6);
    // Column 0 stayed live throughout, so every step mean is finite.
    assert!(report.step_train_loss.iter().all(|l| l.is_finite()));
    assert!(report.final_val_nll.is_finite());
}

#[test]
fn sim_supports_general_gossip_groups() {
    // §3.2's general group size n (paper uses the minimum, 2): n = 3
    // over dp = 3 means every outer step is one whole-row group.
    let Some(mut eng) = engine(2) else { return };
    let mut cfg = cfg_for(Method::NoLoCo, 3, 2, 2);
    cfg.outer.group = 3;
    cfg.outer.gamma =
        noloco::config::OuterConfig::default_gamma(cfg.outer.alpha, 3);
    // dp=3 needs 3 x mb=2 = 6 seqs per step.
    cfg.model.batch_tokens = 3 * 2 * cfg.model.seq_len;
    let mut t = SimTrainer::new(cfg, &mut eng).unwrap();
    let report = t.run().unwrap();
    assert!(report.final_val_nll.is_finite());
    // One 3-member group = 3 pairwise exchanges per stage row.
    assert_eq!(report.comm.pair_exchanges, 2 * 3);
}

/// Golden trajectories: under the `TrainerCore` redesign every method
/// must stay deterministic — same seed, same `RunTrace`, same comm
/// accounting — and the per-method counting invariants pinned above
/// (`fsdp_replicas_stay_bit_identical`, `noloco_diverges…`,
/// `diloco_outer_resets…`) pin the counters to the pre-redesign seed
/// values. This test pins the full trace series bit-for-bit across
/// repeated runs for all three methods.
#[test]
fn golden_trajectories_are_bit_stable_per_method() {
    let Some(mut eng) = engine(2) else { return };
    for method in [Method::Fsdp, Method::DiLoCo, Method::NoLoCo] {
        let mut cfg = cfg_for(method, 2, 2, 4);
        cfg.eval_every = 2;
        let a = SimTrainer::new(cfg.clone(), &mut eng).unwrap().run().unwrap();
        let b = SimTrainer::new(cfg, &mut eng).unwrap().run().unwrap();
        assert_eq!(a.executor, "sim");
        assert_eq!(a.trace.steps, b.trace.steps, "{method}");
        assert_eq!(a.trace.train_loss, b.trace.train_loss, "{method}");
        assert_eq!(a.trace.val_loss, b.trace.val_loss, "{method}");
        assert_eq!(a.trace.weight_std, b.trace.weight_std, "{method}");
        assert_eq!(a.step_train_loss, b.step_train_loss, "{method}");
        assert_eq!(a.comm, b.comm, "{method}");
        assert_eq!(a.step_train_loss.len(), 4, "{method}");
        assert!(a.step_train_loss.iter().all(|l| l.is_finite()), "{method}");
    }
}

/// The threaded executor runs the *same* `SyncStrategy` impls over the
/// fabric communicator: for every method its loss series must track the
/// grid executor's to float tolerance (collective fold order is the only
/// difference).
#[test]
fn threaded_matches_sim_for_all_methods() {
    if !have_artifacts(2) {
        return;
    }
    for method in [Method::Fsdp, Method::DiLoCo, Method::NoLoCo] {
        let cfg = cfg_for(method, 2, 2, 2);
        let mut eng = engine(2).unwrap();
        let sim = SimTrainer::new(cfg.clone(), &mut eng).unwrap().run().unwrap();
        let thr = ThreadedTrainer::new(cfg).with_val_batches(0).run().unwrap();
        assert_eq!(thr.step_train_loss.len(), sim.step_train_loss.len(), "{method}");
        for (a, b) in thr.step_train_loss.iter().zip(&sim.step_train_loss) {
            assert!(
                (a - b).abs() < 1e-4,
                "{method}: threaded {a} vs sim {b} — executors diverged"
            );
        }
        // Logical comm counters agree exactly between executors.
        assert_eq!(
            thr.comm.blocking_collectives, sim.comm.blocking_collectives,
            "{method}"
        );
        assert_eq!(thr.comm.pair_exchanges, sim.comm.pair_exchanges, "{method}");
    }
}

/// The bandwidth-aware pairing policy is selectable end-to-end and keeps
/// NoLoCo's trajectory finite and deterministic on a WAN topology.
#[test]
fn bandwidth_aware_pairing_trains_on_wan() {
    let Some(mut eng) = engine(2) else { return };
    let mut cfg = cfg_for(Method::NoLoCo, 2, 2, 4);
    cfg.pairing = noloco::config::PairingMode::BandwidthAware;
    cfg.net.preset = noloco::config::NetPreset::MultiRegionWan;
    cfg.net.regions = 2;
    let a = SimTrainer::new(cfg.clone(), &mut eng).unwrap().run().unwrap();
    let b = SimTrainer::new(cfg, &mut eng).unwrap().run().unwrap();
    assert!(a.final_val_nll.is_finite());
    assert_eq!(a.final_val_nll, b.final_val_nll);
    // Still gossip: no blocking collectives under the biased policy.
    assert_eq!(a.comm.blocking_collectives, 0);
    assert!(a.comm.pair_exchanges > 0);
}

#[test]
fn run_threaded_convenience_mirrors_trainer() {
    if !have_artifacts(2) {
        return;
    }
    let cfg = cfg_for(Method::NoLoCo, 2, 2, 2);
    let a = noloco::train::run_threaded(&cfg).unwrap();
    assert_eq!(a.executor, "threaded");
    assert_eq!(a.step_train_loss.len(), 2);
    assert!(a.step_train_loss.iter().all(|l| l.is_finite()));
}

#[test]
fn cli_config_plumbs_into_trainer() {
    let Some(mut eng) = engine(2) else { return };
    let args = Args::parse(
        [
            "train", "--preset", "tiny", "--method", "noloco", "--steps", "2", "--dp", "2",
            "--pp", "2", "--set", "train.eval_tokens=512", "--set", "outer.inner_steps=2",
        ]
        .iter()
        .map(|s| s.to_string()),
    )
    .unwrap();
    let cfg = train_config_from(&args).unwrap();
    assert_eq!(cfg.steps, 2);
    assert_eq!(cfg.eval_tokens, 512);
    let report = SimTrainer::new(cfg, &mut eng).unwrap().run().unwrap();
    assert!(report.final_val_nll.is_finite());
}
