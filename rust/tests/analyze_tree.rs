//! Static-analysis gate + map-order bit-neutrality.
//!
//! Three layers:
//!
//! * **Self-check**: the committed source tree must analyze clean under
//!   rules R1–R5 (`noloco analyze` exits 0). This is the same check CI
//!   runs via `scripts/check_analyze.sh`; keeping it in `cargo test`
//!   means a plain test run catches a regression before CI does.
//! * **JSON contract**: `--format json` emits journal-style lines that
//!   [`noloco::obs::parse_line`] accepts, with the documented keys —
//!   the same parser tooling uses for `--trace-out` journals.
//! * **Bit-neutrality of the BTreeMap swaps**: the R2 remediation
//!   replaced every `HashMap`/`HashSet` on fold and sweep paths with
//!   ordered maps. These tests pin the property the swap exists for:
//!   insertion order must not change a single output bit — neither in
//!   the accounting communicator's collect payloads and wire totals,
//!   nor in the checkpoint assembler's merged file bytes.

use noloco::analyze;
use noloco::obs::parse_line;
use noloco::train::{
    AccountingComm, CkptAssembler, Communicator, CoreRecord, LoaderCursor, RankSnapshot,
    WorkerRecord,
};

fn src_root() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("src")
}

/// The committed tree is the first fixture: every finding must have
/// been fixed or annotated before commit, so `analyze` is clean here.
#[test]
fn committed_tree_analyzes_clean() {
    let report = analyze::run_path(&src_root()).expect("walk rust/src");
    assert!(report.files > 20, "suspiciously small tree: {} files", report.files);
    assert!(
        report.clean(),
        "committed tree must analyze clean; findings:\n{}",
        analyze::render_text(&report)
    );
}

/// `--format json` output is line-delimited objects the journal parser
/// accepts: one header (`kind: analyze`) plus one line per finding
/// (`kind: finding`), with the documented keys present and typed.
#[test]
fn json_output_parses_as_journal_lines() {
    let report = analyze::Report {
        files: 3,
        findings: vec![
            analyze::Finding {
                file: "train/core.rs".to_string(),
                line: 42,
                rule: "R1",
                msg: "wall-clock \"read\" on a \\deterministic path".to_string(),
            },
            analyze::Finding {
                file: "net/mod.rs".to_string(),
                line: 7,
                rule: "R2",
                msg: "iteration over HashMap".to_string(),
            },
        ],
    };
    let out = analyze::render_json(&report);
    let lines: Vec<&str> = out.lines().collect();
    assert_eq!(lines.len(), 3, "header + one line per finding:\n{out}");

    let hdr = parse_line(lines[0]).expect("header parses");
    assert_eq!(hdr["kind"].str_val(), Some("analyze"));
    assert_eq!(hdr["v"].uint(), Some(1));
    assert_eq!(hdr["version"].uint(), Some(u64::from(analyze::VERSION)));
    assert_eq!(hdr["files"].uint(), Some(3));
    assert_eq!(hdr["findings"].uint(), Some(2));
    assert_eq!(hdr["clean"].boolean(), Some(false));

    for (line, (file, ln, rule)) in
        lines[1..].iter().zip([("train/core.rs", 42, "R1"), ("net/mod.rs", 7, "R2")])
    {
        let f = parse_line(line).unwrap_or_else(|| panic!("finding line parses: {line}"));
        assert_eq!(f["kind"].str_val(), Some("finding"));
        assert_eq!(f["file"].str_val(), Some(file));
        assert_eq!(f["line"].uint(), Some(ln));
        assert_eq!(f["rule"].str_val(), Some(rule));
        assert!(f["msg"].str_val().is_some(), "msg key present: {line}");
    }

    // A clean report is a single self-contained header line.
    let clean = analyze::Report { files: 3, findings: vec![] };
    let out = analyze::render_json(&clean);
    assert_eq!(out.lines().count(), 1);
    let hdr = parse_line(out.trim()).expect("clean header parses");
    assert_eq!(hdr["clean"].boolean(), Some(true));
}

/// The execution pool's thread auto-detect (`--threads 0` →
/// `available_parallelism`) is an R1 ambient-machine input whose
/// allowance is scoped to `train/par.rs` — the pool's submission-order
/// contract keeps the trajectory identical at any width. The committed
/// pool file must (a) actually exercise the pattern and (b) analyze
/// clean *only* under its own path: the same source moved anywhere else
/// trips R1 again.
#[test]
fn pool_thread_autodetect_allowance_is_scoped() {
    let src = std::fs::read_to_string(src_root().join("train/par.rs")).expect("read train/par.rs");
    assert!(
        src.contains("available_parallelism"),
        "train/par.rs should resolve --threads 0 from the machine width"
    );
    assert!(analyze::analyze_source("train/par.rs", &src).is_empty());
    let elsewhere = analyze::analyze_source("train/core.rs", &src);
    assert!(
        elsewhere.iter().any(|f| f.rule == "R1"),
        "the R1 allowance must not leak beyond train/par.rs"
    );
}

/// Drive one gossip round through an [`AccountingComm`], offering the
/// stage row in the given replica order, and return every collect
/// payload plus the wire totals.
fn round_trip(order: &[usize]) -> (Vec<(Vec<f32>, Vec<f32>)>, (u64, u64)) {
    let mut comm = AccountingComm::new();
    let all = [0usize, 1, 2];
    for &r in order {
        let delta: Vec<f32> = (0..4).map(|i| (r * 10 + i) as f32 * 0.5).collect();
        let phi: Vec<f32> = (0..4).map(|i| (r * 100 + i) as f32 * 0.25).collect();
        let peers: Vec<usize> = all.iter().copied().filter(|&p| p != r).collect();
        comm.offer_round(0, r, &peers, 1, 0, 2, &delta, &phi).expect("offer");
    }
    let mut got = Vec::new();
    for me in all {
        for peer in all {
            if peer == me {
                continue;
            }
            let dp = comm
                .collect_round(0, me, peer, 1, 0, false)
                .expect("collect")
                .expect("offer retained");
            got.push(dp);
        }
    }
    (got, comm.wire_totals())
}

/// Offer insertion order must not change what any collector sees, nor
/// a single accounting counter — the property the HashMap→BTreeMap
/// swap in `train/comm.rs` exists to guarantee (analyze rule R2).
#[test]
fn map_swap_bit_neutrality_accounting_comm() {
    let (a, wa) = round_trip(&[0, 1, 2]);
    let (b, wb) = round_trip(&[2, 0, 1]);
    assert_eq!(wa, wb, "wire totals must not depend on offer order");
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.0, y.0, "delta payload bits differ");
        assert_eq!(x.1, y.1, "phi payload bits differ");
    }
}

fn snap(stage: u32, replica: u32) -> RankSnapshot {
    let n = 6usize;
    let base = (stage * 10 + replica) as f32;
    RankSnapshot {
        step: 8,
        outer_idx: 2,
        worker: WorkerRecord {
            stage,
            replica,
            adam_t: 8,
            theta: (0..n).map(|i| base + i as f32 * 0.125).collect(),
            m: vec![0.5; n],
            v: vec![0.25; n],
            phi: (0..n).map(|i| base - i as f32).collect(),
            delta: vec![0.0; n],
            strategy: None,
        },
        loader: (stage == 0).then_some(LoaderCursor { replica, cursor: 64 + u64::from(replica) }),
        core: CoreRecord { stage, replica, live: vec![true, true], ..CoreRecord::default() },
    }
}

/// The threaded executor's ranks submit snapshots in whatever order
/// their threads reach the cadence. The merged checkpoint file must be
/// byte-identical regardless — the assembler sorts, and its pending
/// map is ordered (analyze rule R2 on `train/checkpoint.rs`).
#[test]
fn ckpt_assembler_submission_order_byte_identity() {
    let dir = std::env::temp_dir();
    let pid = std::process::id();
    let ranks = [(0u32, 0u32), (0, 1), (1, 0), (1, 1)];
    let mut files = Vec::new();
    for (tag, order) in [("fwd", [0usize, 1, 2, 3]), ("rev", [3, 2, 0, 1])] {
        let path = dir.join(format!("noloco_analyze_ck_{pid}_{tag}.bin"));
        let asm = CkptAssembler::new(&path, 2, 2);
        let mut wrote = 0;
        for &i in &order {
            let (s, r) = ranks[i];
            if asm.submit(2, 2, snap(s, r)).expect("submit").is_some() {
                wrote += 1;
            }
        }
        assert_eq!(wrote, 1, "exactly one rank completes the set");
        files.push(std::fs::read(&path).expect("read merged checkpoint"));
        let _ = std::fs::remove_file(&path);
    }
    assert_eq!(files[0], files[1], "merged checkpoint bytes depend on submission order");
}
