//! Cross-language runtime tests: execute the AOT artifacts through the
//! PJRT CPU client and compare against the `golden.toml` statistics the
//! Python side computed with eager JAX at build time.
//!
//! This is the contract test for the whole Rust<->XLA bridge: argument
//! order, layouts, tuple unpacking, and numerics all have to line up for
//! these to pass. Requires `make artifacts` (skips politely otherwise).

use noloco::runtime::{self, funcs, Engine};

const ART: &str = "artifacts";

fn stats(xs: &[f32]) -> (f64, f64, f64, f64) {
    let n = xs.len() as f64;
    let mean = xs.iter().map(|&x| x as f64).sum::<f64>() / n;
    let var = xs.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / n;
    (mean, var.sqrt(), xs[0] as f64, xs[xs.len() - 1] as f64)
}

fn assert_close(got: f64, want: f64, tol: f64, what: &str) {
    let denom = want.abs().max(1e-6);
    assert!(
        ((got - want) / denom).abs() < tol,
        "{what}: got {got}, golden {want} (rel err {:.2e} > {tol})",
        ((got - want) / denom).abs()
    );
}

/// Check `(mean, std, first, last)` of a buffer against golden entries.
fn check_stats(
    golden: &std::collections::BTreeMap<String, f64>,
    prefix: &str,
    xs: &[f32],
    tol: f64,
) {
    let (mean, std, first, last) = stats(xs);
    assert_close(mean, golden[&format!("{prefix}_mean")], tol, &format!("{prefix}_mean"));
    assert_close(std, golden[&format!("{prefix}_std")], tol, &format!("{prefix}_std"));
    assert_close(first, golden[&format!("{prefix}_first")], tol, &format!("{prefix}_first"));
    assert_close(last, golden[&format!("{prefix}_last")], tol, &format!("{prefix}_last"));
}

fn tokens_for(mb: usize, s: usize, vocab: usize) -> Vec<i32> {
    // Must match aot.write_golden: (i*7919 + 13) % vocab.
    (0..mb * s).map(|i| ((i * 7919 + 13) % vocab) as i32).collect()
}

fn engine_for(model: &str, pp: usize) -> Option<Engine> {
    let dir = match runtime::find_build(ART, model, pp) {
        Ok(d) => d,
        Err(e) => {
            // Skip cleanly without compiled artifacts; CI images that ran
            // `make artifacts` set NOLOCO_REQUIRE_ARTIFACTS to turn a
            // missing build into a hard failure instead of a silent skip.
            if std::env::var_os("NOLOCO_REQUIRE_ARTIFACTS").is_some() {
                panic!("NOLOCO_REQUIRE_ARTIFACTS is set but {model}-pp{pp} is missing: {e}");
            }
            eprintln!("skipping: no {model}-pp{pp} artifacts (run `make artifacts`)");
            return None;
        }
    };
    Some(Engine::new(dir).expect("engine"))
}

#[test]
fn staged_build_matches_golden_end_to_end() {
    let Some(mut eng) = engine_for("tiny", 2) else { return };
    let man = eng.manifest().unwrap();
    let golden = runtime::golden(eng.dir()).unwrap();
    let (mb, s, v, h) = (man.mb, man.seq_len, man.vocab, man.hidden);
    let n_first = man.param_count("first").unwrap();
    let n_last = man.param_count("last").unwrap();

    // ---- init ----
    let first = eng
        .execute("first", funcs::INIT, &[runtime::lit_scalar_i32(42)])
        .unwrap();
    let first = runtime::to_vec_f32(&first[0]).unwrap();
    assert_eq!(first.len(), n_first);
    check_stats(&golden, "first_init", &first, 1e-4);

    let last = eng
        .execute("last", funcs::INIT, &[runtime::lit_scalar_i32(43)])
        .unwrap();
    let last = runtime::to_vec_f32(&last[0]).unwrap();
    assert_eq!(last.len(), n_last);
    check_stats(&golden, "last_init", &last, 1e-4);

    // ---- forward chain ----
    let toks = tokens_for(mb, s, v);
    let hidden = eng
        .execute(
            "first",
            funcs::FWD,
            &[
                runtime::lit_f32(&first, &[n_first]).unwrap(),
                runtime::lit_i32(&toks, &[mb, s]).unwrap(),
            ],
        )
        .unwrap();
    let hidden = runtime::to_vec_f32(&hidden[0]).unwrap();
    assert_eq!(hidden.len(), mb * s * h);
    check_stats(&golden, "hidden", &hidden, 1e-3);

    // ---- last-stage backward: (loss, gflat, gx) ----
    let out = eng
        .execute(
            "last",
            funcs::BWD,
            &[
                runtime::lit_f32(&last, &[n_last]).unwrap(),
                runtime::lit_f32(&hidden, &[mb, s, h]).unwrap(),
                runtime::lit_i32(&toks, &[mb, s]).unwrap(),
            ],
        )
        .unwrap();
    assert_eq!(out.len(), 3, "last.bwd returns (loss, gflat, gx)");
    let loss = runtime::to_f32(&out[0]).unwrap() as f64;
    assert_close(loss, golden["loss"], 1e-4, "loss");
    // Untrained model: loss ~= ln(vocab).
    assert!((loss - (v as f64).ln()).abs() < 1.0, "loss {loss}");
    let glast = runtime::to_vec_f32(&out[1]).unwrap();
    check_stats(&golden, "last_grad", &glast, 2e-3);
    let gx = runtime::to_vec_f32(&out[2]).unwrap();
    assert_eq!(gx.len(), mb * s * h);
    check_stats(&golden, "gx", &gx, 2e-3);

    // ---- first-stage backward consumes gx ----
    let gfirst = eng
        .execute(
            "first",
            funcs::BWD,
            &[
                runtime::lit_f32(&first, &[n_first]).unwrap(),
                runtime::lit_i32(&toks, &[mb, s]).unwrap(),
                runtime::lit_f32(&gx, &[mb, s, h]).unwrap(),
            ],
        )
        .unwrap();
    let gfirst = runtime::to_vec_f32(&gfirst[0]).unwrap();
    assert_eq!(gfirst.len(), n_first);
    assert!(gfirst.iter().all(|x| x.is_finite()));
    assert!(gfirst.iter().any(|&x| x != 0.0));

    // ---- Adam artifact vs golden ----
    let g: Vec<f32> = first.iter().map(|&x| 0.01 * x + 0.005).collect();
    let zeros = vec![0.0f32; n_first];
    let out = eng
        .execute(
            "first",
            funcs::ADAM,
            &[
                runtime::lit_f32(&first, &[n_first]).unwrap(),
                runtime::lit_f32(&zeros, &[n_first]).unwrap(),
                runtime::lit_f32(&zeros, &[n_first]).unwrap(),
                runtime::lit_f32(&g, &[n_first]).unwrap(),
                runtime::lit_scalars(&[1e-3, 1.0, 0.9, 0.999, 1e-8, 1.0]),
            ],
        )
        .unwrap();
    assert_eq!(out.len(), 3);
    let f2 = runtime::to_vec_f32(&out[0]).unwrap();
    check_stats(&golden, "adam_flat", &f2, 1e-3);
    let m2 = runtime::to_vec_f32(&out[1]).unwrap();
    check_stats(&golden, "adam_m", &m2, 1e-3);

    // ---- NoLoCo outer artifact vs golden ----
    let delta: Vec<f32> = first.iter().map(|&x| 0.001 * x).collect();
    let dsum: Vec<f32> = first.iter().map(|&x| 0.02 * x + 0.01).collect();
    let psum: Vec<f32> = first.iter().map(|&x| 2.0 * x + 0.1).collect();
    let out = eng
        .execute(
            "first",
            funcs::OUTER_NOLOCO,
            &[
                runtime::lit_f32(&first, &[n_first]).unwrap(),
                runtime::lit_f32(&delta, &[n_first]).unwrap(),
                runtime::lit_f32(&dsum, &[n_first]).unwrap(),
                runtime::lit_f32(&psum, &[n_first]).unwrap(),
                runtime::lit_scalars(&[0.5, 0.7, 0.9, 0.5]),
            ],
        )
        .unwrap();
    assert_eq!(out.len(), 2);
    let phi2 = runtime::to_vec_f32(&out[0]).unwrap();
    check_stats(&golden, "outer_phi", &phi2, 1e-3);
    let delta2 = runtime::to_vec_f32(&out[1]).unwrap();
    check_stats(&golden, "outer_delta", &delta2, 1e-3);

    // Outer artifact must agree with the host-side reference optimizer.
    {
        use noloco::optim::{NolocoOuter, OuterState};
        use noloco::tensor::Tensor;
        let mut st = OuterState::new(&[Tensor::from_vec(first.clone(), &[n_first])]);
        st.delta = vec![Tensor::from_vec(delta.clone(), &[n_first])];
        let opt = NolocoOuter { alpha: 0.5, beta: 0.7, gamma: 0.9 };
        // Reconstruct the group arguments: dsum/psum are group *sums*
        // with n=2 (inv_n = 0.5).
        let d0: Vec<f32> = dsum.iter().map(|&x| 0.5 * x).collect();
        let deltas = vec![
            vec![Tensor::from_vec(d0.clone(), &[n_first])],
            vec![Tensor::from_vec(d0, &[n_first])],
        ];
        let p0: Vec<f32> = psum.iter().map(|&x| 0.5 * x).collect();
        let phis = vec![
            vec![Tensor::from_vec(p0.clone(), &[n_first])],
            vec![Tensor::from_vec(p0, &[n_first])],
        ];
        let theta = vec![Tensor::from_vec(first.clone(), &[n_first])];
        opt.step_group(&mut st, &theta, &deltas, &phis);
        let host = st.phi[0].as_slice();
        let max_err = host
            .iter()
            .zip(&phi2)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_err < 1e-4, "host vs artifact outer step: max err {max_err}");
    }
}

#[test]
fn full_build_matches_golden() {
    let Some(mut eng) = engine_for("tiny", 1) else { return };
    let man = eng.manifest().unwrap();
    let golden = runtime::golden(eng.dir()).unwrap();
    let (mb, s, v) = (man.mb, man.seq_len, man.vocab);
    let n = man.param_count("full").unwrap();

    let flat = eng
        .execute("full", funcs::INIT, &[runtime::lit_scalar_i32(42)])
        .unwrap();
    let flat = runtime::to_vec_f32(&flat[0]).unwrap();
    check_stats(&golden, "full_init", &flat, 1e-4);

    let toks = tokens_for(mb, s, v);
    let out = eng
        .execute(
            "full",
            funcs::BWD,
            &[
                runtime::lit_f32(&flat, &[n]).unwrap(),
                runtime::lit_i32(&toks, &[mb, s]).unwrap(),
            ],
        )
        .unwrap();
    assert_eq!(out.len(), 2, "full.bwd returns (loss, gflat)");
    let loss = runtime::to_f32(&out[0]).unwrap() as f64;
    assert_close(loss, golden["loss"], 1e-4, "full loss");
    let g = runtime::to_vec_f32(&out[1]).unwrap();
    check_stats(&golden, "full_grad", &g, 2e-3);
}

#[test]
fn loss_artifact_matches_bwd_loss() {
    // last.loss (validation path) and last.bwd (training path) must agree
    // on the loss value.
    let Some(mut eng) = engine_for("tiny", 2) else { return };
    let man = eng.manifest().unwrap();
    let (mb, s, v, h) = (man.mb, man.seq_len, man.vocab, man.hidden);
    let n_first = man.param_count("first").unwrap();
    let n_last = man.param_count("last").unwrap();

    let first = eng.execute("first", funcs::INIT, &[runtime::lit_scalar_i32(7)]).unwrap();
    let first = runtime::to_vec_f32(&first[0]).unwrap();
    let last = eng.execute("last", funcs::INIT, &[runtime::lit_scalar_i32(8)]).unwrap();
    let last = runtime::to_vec_f32(&last[0]).unwrap();
    let toks = tokens_for(mb, s, v);
    let hid = eng
        .execute(
            "first",
            funcs::FWD,
            &[
                runtime::lit_f32(&first, &[n_first]).unwrap(),
                runtime::lit_i32(&toks, &[mb, s]).unwrap(),
            ],
        )
        .unwrap();
    let hid = runtime::to_vec_f32(&hid[0]).unwrap();

    let args = [
        runtime::lit_f32(&last, &[n_last]).unwrap(),
        runtime::lit_f32(&hid, &[mb, s, h]).unwrap(),
        runtime::lit_i32(&toks, &[mb, s]).unwrap(),
    ];
    let l1 = runtime::to_f32(&eng.execute("last", funcs::LOSS, &args).unwrap()[0]).unwrap();
    let args = [
        runtime::lit_f32(&last, &[n_last]).unwrap(),
        runtime::lit_f32(&hid, &[mb, s, h]).unwrap(),
        runtime::lit_i32(&toks, &[mb, s]).unwrap(),
    ];
    let l2 = runtime::to_f32(&eng.execute("last", funcs::BWD, &args).unwrap()[0]).unwrap();
    assert!((l1 - l2).abs() < 1e-5, "{l1} vs {l2}");
}

#[test]
fn manifest_agrees_with_rust_model_mirror() {
    // The Python stage_shapes and the Rust mirror must produce identical
    // parameter counts — this is the preset-drift guard.
    use noloco::config::presets;
    use noloco::model::{stage_param_count, StageKind};
    for (name, pp) in [("tiny", 1), ("tiny", 2), ("small", 2), ("e2e", 2)] {
        let Ok(dir) = runtime::find_build(ART, name, pp) else { continue };
        let man = Manifestish::load(&dir);
        let cfg = presets::preset(name).unwrap().model;
        man.0.check_against(&cfg, pp).unwrap();
        for (kind_name, kind) in [
            ("first", StageKind::First),
            ("mid", StageKind::Mid),
            ("last", StageKind::Last),
            ("full", StageKind::Full),
        ] {
            if let Ok(n) = man.0.param_count(kind_name) {
                assert_eq!(
                    n,
                    stage_param_count(&cfg, kind, pp),
                    "{name}-pp{pp} {kind_name}"
                );
            }
        }
    }
}

struct Manifestish(noloco::runtime::Manifest);
impl Manifestish {
    fn load(dir: &std::path::Path) -> Self {
        Manifestish(noloco::runtime::Manifest::load(dir).unwrap())
    }
}

fn rss_mb() -> f64 {
    let s = std::fs::read_to_string("/proc/self/status").unwrap();
    for line in s.lines() {
        if let Some(v) = line.strip_prefix("VmRSS:") {
            return v.trim().trim_end_matches(" kB").trim().parse::<f64>().unwrap() / 1024.0;
        }
    }
    0.0
}

#[test]
fn engine_execute_does_not_leak() {
    // Regression test for the upstream xla-crate bug where
    // `PjRtLoadedExecutable::execute` leaks every input device buffer
    // (~2.5 MB/call at tiny-first sizes — it OOM-killed 25k-step runs).
    // `Engine::execute` works around it via Rust-owned buffers +
    // `execute_b`; RSS across 400 executes must stay flat.
    let Some(mut eng) = engine_for("tiny", 2) else { return };
    let man = eng.manifest().unwrap();
    let n = man.param_count("first").unwrap();
    let flat = vec![0.1f32; n];
    let ins = [
        runtime::lit_f32(&flat, &[n]).unwrap(),
        runtime::lit_f32(&flat, &[n]).unwrap(),
        runtime::lit_f32(&flat, &[n]).unwrap(),
        runtime::lit_f32(&flat, &[n]).unwrap(),
        runtime::lit_scalars(&[1e-3, 1.0, 0.9, 0.999, 1e-8, 1.0]),
    ];
    // Warm (compile + allocator steady state).
    for _ in 0..20 {
        let out = eng.execute("first", funcs::ADAM, &ins).unwrap();
        std::hint::black_box(runtime::to_vec_f32(&out[0]).unwrap());
    }
    let before = rss_mb();
    for _ in 0..400 {
        let out = eng.execute("first", funcs::ADAM, &ins).unwrap();
        std::hint::black_box(runtime::to_vec_f32(&out[0]).unwrap());
    }
    let grown = rss_mb() - before;
    // The old path grew ~1000 MB here; allow generous allocator noise.
    assert!(grown < 100.0, "engine leaked {grown:.0} MB over 400 executes");
}
