//! Journal invariants for the `obs` subsystem.
//!
//! Three layers:
//!
//! * **Offer/fold pairing + bounded ages** (no artifacts): driving the
//!   async boundary engine over a churned membership with an in-memory
//!   hub, every journaled `fold` must be preceded by a matching `offer`
//!   for the same `(round, frag)` pair, and no fold may admit an age
//!   `>= outer.staleness`.
//! * **Wire re-aggregation** (artifact-gated): on a `wan` churn run with
//!   `--staleness 3 --trace-out`, summing the journal's `boundary` +
//!   `drain` events reproduces `TrainReport.comm.bytes_sent` /
//!   `msgs_sent` bit-for-bit, and the `detect` events reproduce
//!   `TrainReport.detected` exactly.
//! * **Streaming / threaded journals** (artifact-gated): the fragmented
//!   streaming path journals the same invariants, and the threaded
//!   executor's per-worker wire deltas sum to the fabric totals.

use std::collections::HashSet;

use noloco::config::{presets, Method, NetPreset, SyncMode, TraceLevel, TrainConfig};
use noloco::model::StageKind;
use noloco::net::topo::ChurnEvent;
use noloco::net::ChurnSchedule;
use noloco::obs::{parse_line, required_keys, Event, ObsHub};
use noloco::runtime::{find_build, Engine};
use noloco::train::{
    AccountingComm, AsyncGossipSync, BoundaryClock, Communicator, SimTrainer, SyncStrategy,
    ThreadedTrainer, WorkerState,
};

const ART: &str = "artifacts";

fn have_artifacts(pp: usize) -> bool {
    match find_build(ART, "tiny", pp) {
        Ok(_) => true,
        Err(e) => {
            if std::env::var_os("NOLOCO_REQUIRE_ARTIFACTS").is_some() {
                panic!("NOLOCO_REQUIRE_ARTIFACTS is set but tiny-pp{pp} is missing: {e}");
            }
            eprintln!("skipping: no tiny-pp{pp} artifacts; run `make artifacts` to enable");
            false
        }
    }
}

fn tmp_path(name: &str) -> String {
    std::env::temp_dir()
        .join(format!("noloco_obs_{}_{name}", std::process::id()))
        .to_string_lossy()
        .into_owned()
}

/// Scan an event stream: every `Fold` must have a prior matching
/// `Offer` (the offerer is the fold's `peer` and vice versa, same
/// `(stage, round, frag)`), and no fold admits `age >= staleness`.
/// Returns the fold count.
fn check_offer_fold_invariants(events: &[Event], staleness: u64) -> usize {
    let mut offered: HashSet<(usize, usize, usize, u64, u16)> = HashSet::new();
    let mut folds = 0;
    for ev in events {
        match ev {
            Event::Offer { stage, replica, peer, round, frag, .. } => {
                offered.insert((*stage, *replica, *peer, *round, *frag));
            }
            Event::Fold { stage, replica, peer, round, frag, age, .. } => {
                assert!(
                    offered.contains(&(*stage, *peer, *replica, *round, *frag)),
                    "fold of round {round} frag {frag} from {peer} at {replica} \
                     has no prior matching offer"
                );
                assert!(
                    *age < staleness,
                    "fold admitted age {age} under staleness {staleness}"
                );
                folds += 1;
            }
            _ => {}
        }
    }
    folds
}

/// Rebuild `Offer` / `Fold` events from journal text — enough for the
/// pairing invariant without reaching into the hub.
fn events_from_journal(journal: &str) -> Vec<Event> {
    let mut out = Vec::new();
    for line in journal.lines() {
        let m = parse_line(line).unwrap();
        let u = |k: &str| m[k].uint().unwrap();
        match m["ev"].str_val().unwrap() {
            "offer" => out.push(Event::Offer {
                stage: u("stage") as usize,
                replica: u("replica") as usize,
                peer: u("peer") as usize,
                round: u("round"),
                frag: u("frag") as u16,
                bytes: u("bytes"),
            }),
            "fold" => out.push(Event::Fold {
                stage: u("stage") as usize,
                replica: u("replica") as usize,
                peer: u("peer") as usize,
                round: u("round"),
                frag: u("frag") as u16,
                age: u("age"),
                bytes: u("bytes"),
            }),
            _ => {}
        }
    }
    out
}

/// Validate every journal line against the schema, sum `(bytes, msgs)`
/// over `boundary` + `drain` lines, and rebuild `detected` from the
/// `detect` lines.
fn reaggregate(journal: &str) -> (u64, u64, Vec<(u64, ChurnEvent)>) {
    let (mut bytes, mut msgs) = (0u64, 0u64);
    let mut detected = Vec::new();
    for line in journal.lines() {
        let m = parse_line(line).unwrap_or_else(|| panic!("unparseable line: {line}"));
        assert_eq!(m["v"].uint(), Some(1), "schema version");
        assert!(m.contains_key("wall") && m.contains_key("sim"), "{line}");
        let ev = m["ev"].str_val().expect("ev key").to_string();
        for key in required_keys(&ev).unwrap_or_else(|| panic!("unknown event `{ev}`")) {
            assert!(m.contains_key(*key), "{ev} line missing {key}: {line}");
        }
        match ev.as_str() {
            "boundary" | "drain" => {
                bytes += m["bytes"].uint().unwrap();
                msgs += m["msgs"].uint().unwrap();
            }
            "detect" => {
                let node = m["node"].uint().unwrap() as usize;
                let b = m["boundary"].uint().unwrap();
                let e = if m["join"].boolean() == Some(true) {
                    ChurnEvent::Join(node)
                } else {
                    ChurnEvent::Leave(node)
                };
                detected.push((b, e));
            }
            _ => {}
        }
    }
    (bytes, msgs, detected)
}

// ---------------------------------------------------------------------
// Offer/fold pairing + bounded ages (no artifacts required)
// ---------------------------------------------------------------------

#[test]
fn async_engine_journal_pairs_offers_with_folds_under_churn() {
    let (dp, staleness, boundaries) = (4usize, 3usize, 8u64);
    let churn = ChurnSchedule::none().leave(2, 1).join(5, 1);
    let mut cfg = presets::preset("tiny").unwrap();
    cfg.topology.dp = dp;
    cfg.outer.inner_steps = 1;
    cfg.outer.staleness = staleness;
    cfg.churn = churn.clone();

    let hub = ObsHub::in_memory(TraceLevel::Step);
    let mut comm = AccountingComm::new();
    comm.set_obs(hub.clone());
    let mut s = AsyncGossipSync::from_config(&cfg);
    let mut workers: Vec<WorkerState> = (0..dp)
        .map(|r| {
            let theta: Vec<f32> = (0..6).map(|i| (i + r + 1) as f32 * 0.25).collect();
            let mut w = WorkerState::new(0, r, StageKind::Full, theta, Method::NoLoCo);
            for p in w.phi.iter_mut() {
                *p *= 0.5;
            }
            w
        })
        .collect();
    let clock = BoundaryClock::new(churn, dp, 1);
    for b in 1..=boundaries {
        // inner_steps = 1: boundary b closes global step b - 1.
        comm.set_obs_boundary(b, b - 1);
        let live: Vec<usize> = (0..dp).filter(|&r| clock.live_at_boundary(r, b)).collect();
        for &r in &live {
            s.offer_outer(&mut comm, &workers[r], &live, b).unwrap();
        }
        for &r in &live {
            s.fold_boundary(&mut comm, &mut workers[r], &live, b).unwrap();
        }
    }

    let events = hub.events();
    let folds = check_offer_fold_invariants(&events, staleness as u64);
    assert!(folds > 0, "the run must fold something");
    // The counter registry is a fold over the same event stream.
    let offers = events.iter().filter(|e| matches!(e, Event::Offer { .. })).count();
    assert_eq!(hub.counter("offers"), offers as u64);
    assert_eq!(hub.counter("folds"), folds as u64);
    // Strategy-private counters arrive through report_obs.
    s.report_obs(&hub);
    assert_eq!(hub.counter("async.admitted"), s.admitted());
    assert_eq!(hub.counter("async.excluded_stale"), s.excluded_stale());
    assert_eq!(hub.counter("async.max_admitted_age"), s.max_admitted_age());
    assert!(s.max_admitted_age() < staleness as u64);
    // The histogram buckets stay inside the staleness window and count
    // every fold exactly once.
    let rep = hub.report();
    assert!(rep.fold_age_hist.len() <= staleness);
    assert_eq!(rep.fold_age_hist.iter().sum::<u64>(), folds as u64);
}

// ---------------------------------------------------------------------
// Wire re-aggregation on the acceptance run (artifact-gated)
// ---------------------------------------------------------------------

fn wan_churn_cfg(steps: usize) -> TrainConfig {
    let mut cfg = presets::preset("tiny").unwrap();
    cfg.topology.dp = 2;
    cfg.topology.pp = 2;
    cfg.steps = steps;
    cfg.warmup = 2;
    cfg.eval_every = 0;
    cfg.eval_tokens = 512;
    cfg.outer.inner_steps = 2;
    cfg.net.preset = NetPreset::MultiRegionWan;
    cfg.sync = SyncMode::Streaming;
    cfg.outer.staleness = 3;
    cfg.churn = ChurnSchedule::none().leave(4, 1).join(8, 1);
    cfg
}

#[test]
fn wan_churn_journal_reaggregates_to_comm_totals_bit_for_bit() {
    if !have_artifacts(2) {
        return;
    }
    let trace = tmp_path("wan.jsonl");
    let metrics = tmp_path("wan_metrics.json");
    let mut cfg = wan_churn_cfg(16);
    cfg.obs.trace_out = Some(trace.clone());
    cfg.obs.metrics_out = Some(metrics.clone());
    // Detection on, with a silence fault (disjoint from the schedule
    // window) so `detect` lines appear: boundary b closes step 2b - 1,
    // so silencing steps [10, 14) misses the heartbeats of boundaries 6
    // and 7 and resumes at boundary 8.
    cfg.detect.enabled = true;
    cfg.detect.misses = 2;

    let dir = find_build(ART, "tiny", 2).unwrap();
    let mut eng = Engine::new(&dir).unwrap();
    let mut t = SimTrainer::new(cfg, &mut eng).unwrap().with_silence(1, 10, 14);
    let report = t.run().unwrap();
    assert!(report.final_val_nll.is_finite());
    assert!(!report.detected.is_empty(), "the silence fault must be detected");

    let journal = std::fs::read_to_string(&trace).unwrap();
    let (bytes, msgs, detected) = reaggregate(&journal);
    assert_eq!(bytes, report.comm.bytes_sent, "journal bytes != comm.bytes_sent");
    assert_eq!(msgs, report.comm.msgs_sent, "journal msgs != comm.msgs_sent");
    assert_eq!(detected, report.detected, "journal detect lines != report.detected");

    // The same pairing/staleness invariants hold in the on-disk stream,
    // and the report's derived tables agree with it.
    let events = events_from_journal(&journal);
    let folds = check_offer_fold_invariants(&events, 3);
    assert_eq!(report.obs.counter("folds"), folds as u64);
    assert_eq!(report.obs.counter("boundaries"), report.obs.boundaries.len() as u64);
    assert!(report.obs.boundary_bytes() <= report.comm.bytes_sent);
    assert_eq!(report.obs.journal_path.as_deref(), Some(trace.as_str()));

    // The live metrics snapshot was written (flat JSON + one array; the
    // flat-line parser skips it, so check shape textually).
    let snap = std::fs::read_to_string(&metrics).unwrap();
    let snap = snap.trim();
    assert!(snap.starts_with("{\"v\":1,\"wall\":"), "{snap}");
    assert!(snap.contains("\"bytes\":") && snap.contains("\"sigma\":"), "{snap}");
    assert!(snap.contains("\"fold_age_hist\":[") && snap.ends_with("]}"), "{snap}");

    std::fs::remove_file(&trace).ok();
    std::fs::remove_file(&metrics).ok();
}

// ---------------------------------------------------------------------
// Streaming journal + threaded executor (artifact-gated)
// ---------------------------------------------------------------------

#[test]
fn streaming_journal_closes_the_wire_invariant_on_the_grid() {
    if !have_artifacts(2) {
        return;
    }
    let trace = tmp_path("stream.jsonl");
    let mut cfg = wan_churn_cfg(12);
    cfg.outer.staleness = 1; // lockstep: the streaming strategy proper
    cfg.stream.fragments = 2;
    cfg.stream.overlap = true;
    cfg.obs.trace_out = Some(trace.clone());
    let dir = find_build(ART, "tiny", 2).unwrap();
    let mut eng = Engine::new(&dir).unwrap();
    let report = SimTrainer::new(cfg, &mut eng).unwrap().run().unwrap();

    let journal = std::fs::read_to_string(&trace).unwrap();
    let (bytes, msgs, _) = reaggregate(&journal);
    assert_eq!(bytes, report.comm.bytes_sent);
    assert_eq!(msgs, report.comm.msgs_sent);
    // Overlapped streaming folds deferred fragments one boundary late:
    // some fold must report age 1, none older.
    let events = events_from_journal(&journal);
    check_offer_fold_invariants(&events, 2);
    assert!(
        events.iter().any(|e| matches!(e, Event::Fold { age: 1, .. })),
        "overlapped streaming must fold at least one boundary-late fragment"
    );
    // The strategy-private counter is registered (possibly zero).
    assert!(report.obs.counters.iter().any(|(k, _)| k == "streaming.dropped_stale"));
    std::fs::remove_file(&trace).ok();
}

#[test]
fn threaded_journal_sums_per_worker_deltas_to_fabric_totals() {
    if !have_artifacts(1) {
        return;
    }
    let trace = tmp_path("threaded.jsonl");
    let mut cfg = presets::preset("tiny").unwrap();
    cfg.topology.dp = 2;
    cfg.topology.pp = 1;
    cfg.steps = 8;
    cfg.warmup = 2;
    cfg.eval_every = 0;
    cfg.eval_tokens = 512;
    cfg.outer.inner_steps = 2;
    cfg.obs.trace_out = Some(trace.clone());
    let report = ThreadedTrainer::new(cfg).run().unwrap();
    assert_eq!(report.executor, "threaded");

    // Every worker journals its own rank-local wire deltas into the one
    // shared hub; their sum is the fabric-wide total the report carries.
    let journal = std::fs::read_to_string(&trace).unwrap();
    let (bytes, msgs, _) = reaggregate(&journal);
    assert_eq!(bytes, report.comm.bytes_sent);
    assert_eq!(msgs, report.comm.msgs_sent);
    check_offer_fold_invariants(&events_from_journal(&journal), 1);
    std::fs::remove_file(&trace).ok();
}
