//! Integration tests for the WAN-topology + elastic-membership subsystem:
//! payload-aware collective costs on heterogeneous networks, shared-seed
//! live-set derivations, and the churn-aware config surface. None of
//! these need PJRT artifacts.

use noloco::collective::{
    pair_average_time_bytes, ring_all_reduce_time_bytes, tree_all_reduce_time_bytes,
    tree_all_reduce_time_over,
};
use noloco::config::{presets, NetPreset, NetTopoConfig};
use noloco::net::topo::{ChurnEvent, ChurnSchedule, Link, Membership, Topology};
use noloco::net::{LatencyModel, SimClock};
use noloco::routing::RoutePlan;

fn wan3() -> Topology {
    Topology::multi_region(
        &[4, 4, 4],
        Link::new(LatencyModel::Constant(1e-3), 1e9),
        Link::new(LatencyModel::LogNormal { mu: (80e-3f64).ln(), sigma: 0.6 }, 1.25e7),
    )
}

#[test]
fn wan_tree_pays_inter_region_links_pairs_can_avoid_them() {
    // The Fig. 5 contrast on a heterogeneous network: the tree all-reduce
    // must cross regions, local pairs need not.
    let payload = 4 << 20; // 4 MiB
    let mut tree = 0.0;
    let mut local_pairs = 0.0;
    let reps = 20;
    for seed in 0..reps {
        let mut c = SimClock::with_topology(wan3(), seed);
        tree += tree_all_reduce_time_bytes(&mut c, payload);
        let mut c = SimClock::with_topology(wan3(), seed + 500);
        // Pairs drawn inside regions: (0,1)(2,3) | (4,5)(6,7) | (8,9)(10,11).
        let pairs: Vec<(usize, usize)> = (0..6).map(|k| (2 * k, 2 * k + 1)).collect();
        local_pairs += pair_average_time_bytes(&mut c, Some(&pairs), payload);
    }
    let (tree, local_pairs) = (tree / reps as f64, local_pairs / reps as f64);
    assert!(
        tree > 10.0 * local_pairs,
        "cross-region tree should dwarf intra-region gossip: {tree:.3} vs {local_pairs:.3}"
    );
}

#[test]
fn ring_beats_tree_on_bandwidth_bound_wan_payloads() {
    // The ring ships 1/n-sized chunks, the tree full payloads: with fat
    // payloads over thin links the ring's bandwidth advantage shows even
    // though it pays 2(n-1) latency hops.
    let payload = 64 << 20; // 64 MiB across 12.5 MB/s inter-region links
    let mut c = SimClock::with_topology(wan3(), 1);
    let tree = tree_all_reduce_time_bytes(&mut c, payload);
    let mut c = SimClock::with_topology(wan3(), 1);
    let ring = ring_all_reduce_time_bytes(&mut c, payload);
    assert!(ring < tree, "ring {ring:.1} should beat tree {tree:.1} on fat payloads");
}

#[test]
fn live_subset_collective_ignores_the_departed() {
    // After a leave, the surviving members' tree completes and the dead
    // node's clock never moves — no global stall on the survivor side.
    let mut c = SimClock::with_topology(wan3(), 2);
    let mut member = Membership::full(12);
    member.apply(ChurnEvent::Leave(5));
    let live = member.live_nodes();
    let t = tree_all_reduce_time_over(&mut c, &live, 1 << 20);
    assert!(t > 0.0);
    assert_eq!(c.ready_at(5), 0.0, "departed node must not be waited on");
    for &w in &live {
        assert!((c.ready_at(w) - t).abs() < 1e-9, "member {w} not at the barrier");
    }
}

#[test]
fn shared_seed_live_derivations_agree_across_workers() {
    // Two independent "workers" with the same schedule + seed derive
    // identical live masks, route plans, and (via the mask) gossip pair
    // spaces at every step — the zero-coordination property the threaded
    // trainer relies on.
    let schedule = ChurnSchedule::none().leave(3, 1).join(7, 1).leave(9, 4);
    let dp = 6;
    for step in 0..12u64 {
        let a_mask = schedule.live_at(dp, step);
        let b_mask = schedule.live_at(dp, step);
        assert_eq!(a_mask, b_mask);
        let live: Vec<usize> = (0..dp).filter(|&r| a_mask[r]).collect();
        let a = RoutePlan::for_step_over(noloco::config::Routing::Random, &live, dp, 3, 42, step);
        let b = RoutePlan::for_step_over(noloco::config::Routing::Random, &live, dp, 3, 42, step);
        assert_eq!(a, b);
        // Every live path stays inside the live set.
        for &r0 in &live {
            for &hop in &a.path_from(r0) {
                assert!(a_mask[hop]);
            }
        }
    }
}

#[test]
fn churn_config_round_trips_into_presets() {
    let mut cfg = presets::preset("tiny").unwrap();
    cfg.churn = ChurnSchedule::parse("leave:4:1;join:8:1").unwrap();
    cfg.validate().unwrap();
    assert_eq!(cfg.churn.events_at(4).collect::<Vec<_>>(), vec![ChurnEvent::Leave(1)]);
    // DiLoCo configs carry churn through validation (the trainers reject
    // it at run time, where the all-reduce would have to stall).
    let d = presets::as_diloco(cfg.clone());
    d.validate().unwrap();
}

#[test]
fn net_preset_build_covers_uneven_region_splits() {
    let cfg = NetTopoConfig {
        preset: NetPreset::MultiRegionWan,
        regions: 5,
        ..NetTopoConfig::default()
    };
    let t = cfg.build(13, 0);
    assert_eq!(t.world(), 13);
    assert_eq!(t.regions(), 5);
    let mut sizes = vec![0usize; 5];
    for n in 0..13 {
        sizes[t.region_of(n)] += 1;
    }
    assert_eq!(sizes, vec![3, 3, 3, 2, 2]);
}

#[test]
fn straggler_gates_wan_collectives_not_unrelated_pairs() {
    let topo = || wan3().with_straggler(11, 5.0);
    let mut c = SimClock::with_topology(topo(), 3);
    let with_straggler = tree_all_reduce_time_bytes(&mut c, 1 << 20);
    let mut c = SimClock::with_topology(wan3(), 3);
    let without = tree_all_reduce_time_bytes(&mut c, 1 << 20);
    assert!(
        with_straggler > without,
        "straggler must slow the barrier: {with_straggler:.3} vs {without:.3}"
    );
    // A pair that avoids the straggler is unaffected by its existence.
    let mut c = SimClock::with_topology(topo(), 4);
    let a = pair_average_time_bytes(&mut c, Some(&[(0, 1)]), 1 << 20);
    let mut c = SimClock::with_topology(wan3(), 4);
    let b = pair_average_time_bytes(&mut c, Some(&[(0, 1)]), 1 << 20);
    assert_eq!(a, b);
}
