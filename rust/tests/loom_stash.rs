//! Loom model of the fabric's stash discipline (opt-in).
//!
//! The threaded executor's correctness rests on a small concurrency
//! contract in `net/fabric.rs`:
//!
//! * each [`Endpoint`] stash is single-owner — only the channel and the
//!   `Shared` counters cross threads;
//! * a receiver drains its channel into the stash and matches by tag,
//!   so out-of-order arrival never loses or reorders a tagged message;
//! * the shared send counters are updated under a mutex whose poisoning
//!   is absorbed (`locked`), so a panicking peer cannot wedge metering.
//!
//! Loom cannot instrument `std::sync` / `std::sync::mpsc` directly, so
//! this file models the same shapes with `loom` primitives — a mutexed
//! queue as the wire, a local stash at the receiver, a mutexed counter
//! vector as `Shared` — and exhaustively explores every interleaving.
//!
//! The whole file is behind `cfg(loom)`: a normal `cargo test` compiles
//! it to an empty crate (no loom dependency needed). The nightly CI
//! `sanitize` job appends the loom dependency to Cargo.toml and runs
//! `RUSTFLAGS="--cfg loom" cargo test --test loom_stash` — see
//! `.github/workflows/ci.yml` and docs/ARCHITECTURE.md.
#![cfg(loom)]

use loom::sync::{Arc, Mutex};
use loom::thread;
use std::collections::VecDeque;

/// Tagged frame, standing in for `fabric::Message`.
#[derive(Clone, Debug, PartialEq, Eq)]
struct Msg {
    tag: u32,
    payload: u32,
}

/// The wire: a mutexed queue (the loom stand-in for the mpsc channel)
/// plus the shared per-rank send counter (the `Shared` stand-in).
struct Wire {
    queue: Mutex<VecDeque<Msg>>,
    sent: Mutex<Vec<u64>>,
}

/// Poison-absorbing lock — the same idiom as `fabric::locked`.
fn locked<T>(m: &Mutex<T>) -> loom::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Receiver half: drain the wire into a local stash, then match by tag
/// — mirrors `Endpoint::drain_into_stash` + `try_recv_ready`.
struct Rx {
    wire: Arc<Wire>,
    stash: Vec<Msg>,
}

impl Rx {
    fn drain(&mut self) {
        let mut q = locked(&self.wire.queue);
        while let Some(m) = q.pop_front() {
            self.stash.push(m);
        }
    }

    /// Non-blocking: `None` means "not arrived yet", never "lost".
    fn try_collect(&mut self, tag: u32) -> Option<Msg> {
        self.drain();
        let i = self.stash.iter().position(|m| m.tag == tag)?;
        Some(self.stash.swap_remove(i))
    }

    /// Blocking collect, with loom-visible scheduling points.
    fn collect(&mut self, tag: u32) -> Msg {
        loop {
            if let Some(m) = self.try_collect(tag) {
                return m;
            }
            thread::yield_now();
        }
    }

    /// Stash-expiry sweep — mirrors `Endpoint::sweep_stash`.
    fn sweep<F: FnMut(u32) -> bool>(&mut self, mut keep: F) -> usize {
        self.drain();
        let before = self.stash.len();
        self.stash.retain(|m| keep(m.tag));
        before - self.stash.len()
    }
}

fn send(wire: &Wire, rank: usize, msg: Msg) {
    locked(&wire.queue).push_back(msg);
    locked(&wire.sent)[rank] += 1;
}

/// Out-of-order arrival: the sender emits tags 2, 1, 3; the receiver
/// collects 1 then 2 (stashing whatever arrived early), sweeps tag 3
/// as expired. Under every interleaving: both collects return the
/// right payloads, the sweep drops exactly the expired frame, and the
/// counters account for all three sends.
#[test]
fn stash_matches_out_of_order_under_all_interleavings() {
    loom::model(|| {
        let wire = Arc::new(Wire {
            queue: Mutex::new(VecDeque::new()),
            sent: Mutex::new(vec![0, 0]),
        });
        let tx = wire.clone();
        let sender = thread::spawn(move || {
            send(&tx, 1, Msg { tag: 2, payload: 20 });
            send(&tx, 1, Msg { tag: 1, payload: 10 });
            send(&tx, 1, Msg { tag: 3, payload: 30 });
        });

        let mut rx = Rx { wire: wire.clone(), stash: Vec::new() };
        assert_eq!(rx.collect(1).payload, 10);
        assert_eq!(rx.collect(2).payload, 20);
        sender.join().unwrap();

        // Everything sent is now stash-visible; only tag 3 survives to
        // the sweep and the sweep reclaims exactly it.
        assert_eq!(rx.sweep(|t| t < 3), 1);
        assert_eq!(rx.sweep(|t| t < 3), 0, "sweep is idempotent");
        assert!(rx.stash.is_empty(), "no unexpired frame left behind");
        assert_eq!(*locked(&wire.sent), vec![0, 3]);
    });
}

/// Two senders interleave on the same wire; the receiver's per-tag
/// matching must never cross payloads between them, and the shared
/// counter must see every send exactly once.
#[test]
fn concurrent_senders_never_cross_tags() {
    loom::model(|| {
        let wire = Arc::new(Wire {
            queue: Mutex::new(VecDeque::new()),
            sent: Mutex::new(vec![0, 0, 0]),
        });
        let handles: Vec<_> = [1usize, 2]
            .into_iter()
            .map(|rank| {
                let tx = wire.clone();
                thread::spawn(move || {
                    let tag = rank as u32;
                    send(&tx, rank, Msg { tag, payload: 100 * tag });
                })
            })
            .collect();

        let mut rx = Rx { wire: wire.clone(), stash: Vec::new() };
        assert_eq!(rx.collect(2).payload, 200);
        assert_eq!(rx.collect(1).payload, 100);
        for h in handles {
            h.join().unwrap();
        }
        assert!(rx.stash.is_empty());
        assert_eq!(*locked(&wire.sent), vec![0, 1, 1]);
    });
}
