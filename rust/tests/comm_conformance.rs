//! Cross-transport conformance suite for the [`Communicator`] trait.
//!
//! One generic harness, three implementations:
//!
//! * [`AccountingComm`] — the grid executor's shared in-process maps;
//! * [`FabricComm`] — per-thread endpoints over the in-process fabric;
//! * [`SocketComm`] — per-process endpoints over real loopback TCP
//!   (the full join handshake runs for every world).
//!
//! Every test drives the *shared* contract through `&mut dyn
//! Communicator`: two-phase offer-before-fold ordering, round retention
//! inside the staleness window, stash expiry at the `expire_stale`
//! cutoff, never-blocking heartbeat polls, unmetered replay hooks, and
//! the once-per-pair metering rules that make summed per-rank stats
//! reproduce the grid totals.
//!
//! Documented divergences that are deliberately *not* asserted beyond
//! "no longer collectable":
//!
//! * the accounting communicator errors on a missing state/fragment
//!   collect where the endpoint communicators time out to `None`;
//! * accounting heartbeats are level-triggered (a stored
//!   high-water-mark) while endpoint polls consume one control message
//!   per probe.
//!
//! Because socket delivery is asynchronous (reader threads feed a
//! mailbox), ordering matters: retention/expiry assertions always
//! collect with `wait = true` first — which both proves arrival and, on
//! the endpoint transports, stashes the payload back — and heartbeat
//! presence is probed with a bounded retry loop of individually
//! non-blocking polls.

use std::net::TcpListener;
use std::time::Duration;

use noloco::net::{Channel, Fabric, SocketEndpoint};
use noloco::train::{
    AccountingComm, CommStats, Communicator, EndpointComm, FabricComm, SocketComm,
};

/// Straggler tolerance for the endpoint worlds: generous enough that a
/// loopback hop never falsely times out, short enough that the two
/// deliberate absent-fragment waits stay cheap.
const TIMEOUT: Duration = Duration::from_millis(1500);

/// Cap on the heartbeat retry loop (each poll is non-blocking).
const HB_RETRIES: usize = 2000;

const WORLD: usize = 2;
const STAGE: usize = 0;

// ---------------------------------------------------------------------
// Harness: one world per Communicator implementation
// ---------------------------------------------------------------------

trait CommWorld {
    fn name(&self) -> &'static str;
    /// What `Communicator::executor` must report for this transport.
    fn expect_executor(&self) -> &'static str;
    /// Whether this transport can hand a joiner a live donor's state.
    fn expect_joinable(&self) -> bool;
    /// Rank `rank`'s view of the world.
    fn comm(&mut self, rank: usize) -> &mut dyn Communicator;
    /// Fold a counter over every rank's stats exactly once (the shared
    /// accounting world has a single stats block; endpoint worlds sum).
    fn sum_stat(&self, f: &dyn Fn(&CommStats) -> u64) -> u64;
}

struct AccountingWorld {
    comm: AccountingComm,
}

impl CommWorld for AccountingWorld {
    fn name(&self) -> &'static str {
        "accounting"
    }
    fn expect_executor(&self) -> &'static str {
        "sim"
    }
    fn expect_joinable(&self) -> bool {
        true
    }
    fn comm(&mut self, _rank: usize) -> &mut dyn Communicator {
        &mut self.comm
    }
    fn sum_stat(&self, f: &dyn Fn(&CommStats) -> u64) -> u64 {
        f(self.comm.stats())
    }
}

struct EndpointWorld<E: Channel> {
    name: &'static str,
    executor: &'static str,
    comms: Vec<EndpointComm<E>>,
}

impl<E: Channel> CommWorld for EndpointWorld<E> {
    fn name(&self) -> &'static str {
        self.name
    }
    fn expect_executor(&self) -> &'static str {
        self.executor
    }
    fn expect_joinable(&self) -> bool {
        false
    }
    fn comm(&mut self, rank: usize) -> &mut dyn Communicator {
        &mut self.comms[rank]
    }
    fn sum_stat(&self, f: &dyn Fn(&CommStats) -> u64) -> u64 {
        self.comms.iter().map(|c| f(c.stats())).sum()
    }
}

fn accounting_world() -> Box<dyn CommWorld> {
    Box::new(AccountingWorld { comm: AccountingComm::new() })
}

fn fabric_world() -> Box<dyn CommWorld> {
    let mut fabric = Fabric::new(WORLD);
    let comms = fabric
        .take_endpoints()
        .into_iter()
        .map(|ep| FabricComm::new(ep, WORLD, Some(TIMEOUT)))
        .collect();
    Box::new(EndpointWorld { name: "fabric", executor: "threaded", comms })
}

/// Bootstrap a 2-rank loopback TCP world: reserve an ephemeral seed
/// port, run the joiner handshake on a helper thread, seed on ours.
fn socket_world() -> Box<dyn CommWorld> {
    let probe = TcpListener::bind("127.0.0.1:0").expect("probe bind");
    let seed_addr = probe.local_addr().expect("probe addr").to_string();
    drop(probe); // free the port for the actual seed rank
    let addr = seed_addr.clone();
    let joiner = std::thread::spawn(move || {
        SocketEndpoint::bootstrap(1, WORLD, &addr, "127.0.0.1:0").expect("rank 1 bootstrap")
    });
    let e0 = SocketEndpoint::bootstrap(0, WORLD, &seed_addr, "127.0.0.1:0")
        .expect("rank 0 bootstrap");
    let e1 = joiner.join().expect("joiner thread");
    let comms = vec![
        SocketComm::new(e0, WORLD, Some(TIMEOUT)),
        SocketComm::new(e1, WORLD, Some(TIMEOUT)),
    ];
    Box::new(EndpointWorld { name: "socket", executor: "socket", comms })
}

fn worlds() -> Vec<Box<dyn CommWorld>> {
    vec![accounting_world(), fabric_world(), socket_world()]
}

/// Bounded retry over non-blocking heartbeat polls; `true` if the
/// heartbeat became visible within the cap.
fn poll_until(comm: &mut dyn Communicator, peer: usize, boundary: u32) -> bool {
    for _ in 0..HB_RETRIES {
        if comm.poll_heartbeat(STAGE, 0, peer, boundary).expect("poll") {
            return true;
        }
        std::thread::sleep(Duration::from_millis(1));
    }
    false
}

// ---------------------------------------------------------------------
// Conformance tests (each runs against all three worlds)
// ---------------------------------------------------------------------

#[test]
fn executors_report_their_transport_names() {
    for mut w in worlds() {
        let name = w.name();
        let (exec, join) = (w.expect_executor(), w.expect_joinable());
        let c = w.comm(0);
        assert_eq!(c.executor(), exec, "{name} executor name");
        assert_eq!(c.supports_join_bootstrap(), join, "{name} join capability");
    }
}

#[test]
fn absent_round_poll_returns_none_without_blocking() {
    // Fallback folds consult only what already arrived: a `wait = false`
    // collect of a never-offered round is `Ok(None)` on every transport,
    // instantly — no sleep, no timeout, no error.
    for mut w in worlds() {
        let name = w.name();
        let got = w.comm(0).collect_round(STAGE, 0, 1, 7, 0, false).expect("collect");
        assert!(got.is_none(), "{name}: phantom round offered");
    }
}

#[test]
fn offered_rounds_fold_and_stay_readable_in_window() {
    // Two-phase ordering: the offer lands first, then the fold collects
    // it — and a round stays *re-readable* for its whole retention
    // window (a later boundary may re-admit the same offer at a higher
    // age), on the maps and on the endpoint stash alike.
    let (delta, phi) = (vec![1.5f32, -2.0], vec![0.25f32, 8.0]);
    for mut w in worlds() {
        let name = w.name();
        w.comm(1).offer_round(STAGE, 1, &[0], 3, 0, 2, &delta, &phi).expect("offer");
        let got = w.comm(0).collect_round(STAGE, 0, 1, 3, 0, true).expect("collect");
        assert_eq!(got, Some((delta.clone(), phi.clone())), "{name}: first fold");
        let again = w.comm(0).collect_round(STAGE, 0, 1, 3, 0, false).expect("re-collect");
        assert_eq!(again, Some((delta.clone(), phi.clone())), "{name}: window re-read");
    }
}

#[test]
fn expire_stale_reclaims_rounds_before_cutoff() {
    let (delta, phi) = (vec![4.0f32], vec![-1.0f32]);
    for mut w in worlds() {
        let name = w.name();
        // Prove arrival first: the waiting collect both confirms delivery
        // and (on endpoints) stashes the payload back for the sweep.
        w.comm(1).offer_round(STAGE, 1, &[0], 3, 0, 2, &delta, &phi).expect("offer");
        let got = w.comm(0).collect_round(STAGE, 0, 1, 3, 0, true).expect("collect");
        assert!(got.is_some(), "{name}: round 3 never arrived");
        let removed = w.comm(0).expire_stale(4);
        assert!(removed >= 1, "{name}: expiry swept nothing");
        let stale = w.comm(0).collect_round(STAGE, 0, 1, 3, 0, false).expect("stale poll");
        assert!(stale.is_none(), "{name}: expired round still readable");
        // The channel survives the sweep: a fresh round flows normally.
        w.comm(1).offer_round(STAGE, 1, &[0], 5, 0, 2, &delta, &phi).expect("re-offer");
        let fresh = w.comm(0).collect_round(STAGE, 0, 1, 5, 0, true).expect("fresh collect");
        assert_eq!(fresh, Some((delta.clone(), phi.clone())), "{name}: post-sweep round");
    }
}

#[test]
fn heartbeat_polls_never_block_and_deliver() {
    for mut w in worlds() {
        let name = w.name();
        // Nothing sent yet: the poll answers false immediately.
        let silent = w.comm(0).poll_heartbeat(STAGE, 0, 1, 9).expect("silent poll");
        assert!(!silent, "{name}: phantom heartbeat");
        w.comm(1).send_heartbeat(STAGE, 1, &[0], 9).expect("send heartbeat");
        assert!(poll_until(w.comm(0), 1, 9), "{name}: heartbeat never arrived");
    }
}

#[test]
fn replay_hooks_are_unmetered_and_refill_the_state() {
    // Checkpoint replay re-injects in-flight offers without perturbing a
    // single counter: neither the logical stats nor the wire totals may
    // move, yet the replayed round must fold normally at the peer.
    let (delta, phi) = (vec![7.0f32, 7.5], vec![0.0f32, -3.0]);
    for mut w in worlds() {
        let name = w.name();
        let stats_before = w.comm(1).stats().clone();
        let wire_before = w.comm(1).wire_totals();
        w.comm(1).replay_round(STAGE, 1, &[0], 2, 0, &delta, &phi).expect("replay round");
        w.comm(1).replay_heartbeat(STAGE, 1, &[0], 5).expect("replay heartbeat");
        assert_eq!(w.comm(1).stats(), &stats_before, "{name}: replay metered stats");
        assert_eq!(w.comm(1).wire_totals(), wire_before, "{name}: replay metered wire");
        let got = w.comm(0).collect_round(STAGE, 0, 1, 2, 0, true).expect("collect");
        assert_eq!(got, Some((delta.clone(), phi.clone())), "{name}: replayed round lost");
        assert!(poll_until(w.comm(0), 1, 5), "{name}: replayed heartbeat lost");
    }
}

#[test]
fn fragment_gc_drops_offers_two_rounds_back() {
    // A fragment from round r is collectable through round r + 1 and
    // gone once the world reaches r + 2 (sender-side retention on the
    // accounting maps, receiver-side consumption + expiry sweep on the
    // endpoints). "Gone" is transport-flavoured — an error on the
    // accounting maps, a timeout `None` on the endpoints — so the
    // conformance claim is only: never `Some`.
    let (d1, p1) = (vec![1.0f32], vec![2.0f32]);
    let (d3, p3) = (vec![3.0f32], vec![4.0f32]);
    for mut w in worlds() {
        let name = w.name();
        w.comm(1).offer_fragment(STAGE, 1, &[0], 1, 0, &d1, &p1).expect("offer seq 1");
        let got = w.comm(0).collect_fragment(STAGE, 0, 1, 1, 0).expect("collect seq 1");
        assert_eq!(got, Some((d1.clone(), p1.clone())), "{name}: live fragment");
        // Two rounds later: the new offer triggers sender-side GC, the
        // boundary sweep reclaims any stashed leftovers.
        w.comm(1).offer_fragment(STAGE, 1, &[0], 3, 0, &d3, &p3).expect("offer seq 3");
        w.comm(0).expire_stale(2);
        let stale = w.comm(0).collect_fragment(STAGE, 0, 1, 1, 0);
        assert!(
            !matches!(stale, Ok(Some(_))),
            "{name}: fragment survived two rounds past its offer"
        );
        let live = w.comm(0).collect_fragment(STAGE, 0, 1, 3, 0).expect("collect seq 3");
        assert_eq!(live, Some((d3.clone(), p3.clone())), "{name}: current fragment");
    }
}

#[test]
fn gossip_state_exchanges_symmetrically() {
    // One full outer gossip round: both sides offer, both sides fold the
    // partner's (Δ, φ) — the §4 two-phase exchange, on every transport.
    let (d0, p0) = (vec![10.0f32, 11.0], vec![12.0f32, 13.0]);
    let (d1, p1) = (vec![20.0f32, 21.0], vec![22.0f32, 23.0]);
    for mut w in worlds() {
        let name = w.name();
        w.comm(0).offer_state(STAGE, 0, &[1], 1, &d0, &p0).expect("rank 0 offer");
        w.comm(1).offer_state(STAGE, 1, &[0], 1, &d1, &p1).expect("rank 1 offer");
        let at0 = w.comm(0).collect_state(STAGE, 0, 1, 1).expect("rank 0 collect");
        assert_eq!(at0, Some((d1.clone(), p1.clone())), "{name}: rank 0 fold");
        let at1 = w.comm(1).collect_state(STAGE, 1, 0, 1).expect("rank 1 collect");
        assert_eq!(at1, Some((d0.clone(), p0.clone())), "{name}: rank 1 fold");
    }
}

#[test]
fn offer_metering_counts_pairs_once_across_ranks() {
    // The once-per-pair rule: only the lower-numbered side of a symmetric
    // exchange counts the pair, so summing every rank's stats reproduces
    // the grid executor's totals instead of doubling them.
    let (delta, phi) = (vec![1.0f32, 2.0, 3.0], vec![4.0f32, 5.0, 6.0]);
    let n = (delta.len() + phi.len()) as u64;
    for mut w in worlds() {
        let name = w.name();
        w.comm(0).offer_round(STAGE, 0, &[1], 1, 0, 2, &delta, &phi).expect("rank 0 offer");
        w.comm(1).offer_round(STAGE, 1, &[0], 1, 0, 2, &delta, &phi).expect("rank 1 offer");
        assert_eq!(
            w.sum_stat(&|s| s.pair_exchanges),
            1,
            "{name}: symmetric pair counted once"
        );
        assert_eq!(
            w.sum_stat(&|s| s.floats_sent),
            2 * n,
            "{name}: both sides ship one (Δ, φ) row"
        );
    }
}
