//! Public-API tests for the pluggable synchronization layer: the
//! `SyncStrategy` factory, the `PairingPolicy` contract (valid perfect
//! matchings over any live set), and the golden shared-seed derivation
//! both executors rely on. None of these need PJRT artifacts.

use noloco::config::{presets, Method, NetPreset, NetTopoConfig, PairingMode};
use noloco::rngx::Pcg64;
use noloco::train::{
    strategy_for_config, BandwidthAwarePairing, ChurnResponse, CommPattern, PairingPolicy,
    SyncStrategy, UniformPairing,
};

fn assert_partition(groups: &[Vec<usize>], live: &[usize], group: usize) {
    let mut seen: Vec<usize> = groups.iter().flatten().copied().collect();
    seen.sort_unstable();
    let mut want = live.to_vec();
    want.sort_unstable();
    assert_eq!(seen, want, "every live replica exactly once");
    assert!(
        groups.iter().filter(|g| g.len() < group).count() <= 1,
        "at most one leftover group"
    );
}

#[test]
fn factory_exposes_method_contracts() {
    let base = presets::preset("tiny").unwrap();
    let noloco = strategy_for_config(&base);
    assert_eq!(noloco.name(), "noloco");
    assert_eq!(noloco.pattern(), CommPattern::GossipPairs);
    assert_eq!(noloco.churn_response(), ChurnResponse::Repair);
    let fsdp = strategy_for_config(&presets::as_fsdp(base.clone()));
    assert_eq!(fsdp.pattern(), CommPattern::AllReduce);
    assert_eq!(fsdp.churn_response(), ChurnResponse::Abort);
    assert!(!fsdp.has_outer());
    let diloco = strategy_for_config(&presets::as_diloco(base.clone()));
    assert_eq!(diloco.pattern(), CommPattern::AllReduce);
    assert!(diloco.has_outer());
    // Bandwidth-aware NoLoCo resolves through the same factory.
    let mut cfg = base;
    cfg.pairing = PairingMode::BandwidthAware;
    cfg.net.preset = NetPreset::MultiRegionWan;
    assert_eq!(strategy_for_config(&cfg).name(), "noloco");
}

#[test]
fn uniform_policy_is_the_seed_derivation() {
    // Both pre-redesign executors drew pairs from
    // Pcg64(seed ^ 0x9055 ^ (stage << 40) ^ outer_idx) over live
    // positions; the policy must reproduce that draw exactly so golden
    // trajectories survive the redesign.
    let live = [1usize, 2, 4, 7];
    for (seed, stage, outer_idx) in [(7u64, 0usize, 1u64), (0x0107c0, 1, 3), (123, 2, 50)] {
        let mut prng = Pcg64::seed_from_u64(seed ^ 0x9055 ^ ((stage as u64) << 40) ^ outer_idx);
        let want: Vec<Vec<usize>> = prng
            .random_pairs(live.len())
            .into_iter()
            .map(|(a, b)| match b {
                Some(b) => vec![live[a], live[b]],
                None => vec![live[a]],
            })
            .collect();
        assert_eq!(UniformPairing.draw(&live, 2, stage, outer_idx, seed), want);
    }
}

#[test]
fn property_policies_yield_perfect_matchings_under_churn() {
    let wan = NetTopoConfig {
        preset: NetPreset::MultiRegionWan,
        regions: 4,
        ..NetTopoConfig::default()
    };
    noloco::prop::run("pairing stays a perfect matching as the live set churns", 100, |g| {
        let dp = g.usize_in(2, 20).max(2);
        let seed = g.rng().next_u64();
        let ba = BandwidthAwarePairing::new(wan.build(dp, seed));
        let mut live: Vec<bool> = vec![true; dp];
        for outer_idx in 1..=10u64 {
            // Random leave or join, keeping at least two live replicas.
            let target = g.usize_in(0, dp - 1);
            if g.bool() {
                live[target] = true;
            } else if live.iter().filter(|&&l| l).count() > 2 {
                live[target] = false;
            }
            let live_idx: Vec<usize> = (0..dp).filter(|&r| live[r]).collect();
            for group in [2usize, 3] {
                assert_partition(
                    &UniformPairing.draw(&live_idx, group, 1, outer_idx, seed),
                    &live_idx,
                    group,
                );
                assert_partition(&ba.draw(&live_idx, group, 1, outer_idx, seed), &live_idx, group);
            }
        }
    });
}

#[test]
fn bandwidth_aware_biases_pairs_intra_region() {
    // 16 replicas over 4 regions of 4: biased rounds draw only
    // intra-region pairs; the periodic uniform rounds mix across regions.
    let wan = NetTopoConfig {
        preset: NetPreset::MultiRegionWan,
        regions: 4,
        ..NetTopoConfig::default()
    };
    let dp = 16;
    let topo = wan.build(dp, 3);
    let ba = BandwidthAwarePairing::new(wan.build(dp, 3));
    let live: Vec<usize> = (0..dp).collect();
    let (mut biased_cross, mut any_cross) = (0usize, 0usize);
    for outer_idx in 1..=80u64 {
        let cross = ba
            .draw(&live, 2, 0, outer_idx, 5)
            .iter()
            .filter(|g| g.len() == 2 && topo.region_of(g[0]) != topo.region_of(g[1]))
            .count();
        any_cross += cross;
        if outer_idx % 4 != 0 {
            biased_cross += cross;
        }
    }
    assert_eq!(biased_cross, 0, "even regions: biased rounds never cross");
    assert!(any_cross > 0, "uniform rounds must keep the gossip graph mixing");
}

#[test]
fn method_parse_reaches_every_strategy() {
    for (s, m) in [
        ("fsdp", Method::Fsdp),
        ("diloco", Method::DiLoCo),
        ("noloco", Method::NoLoCo),
    ] {
        assert_eq!(Method::parse(s), Some(m));
    }
}
