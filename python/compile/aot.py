"""AOT lowering: Layer-2 JAX stages -> HLO text artifacts for the Rust runtime.

Every function the Rust coordinator executes at training time is lowered
here, once, at build time (``make artifacts``). The interchange format is
**HLO text** (not a serialized ``HloModuleProto``): jax >= 0.5 emits protos
with 64-bit instruction ids which the pinned xla_extension 0.5.1 rejects;
the HLO text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Artifact layout (one directory per build)::

    artifacts/<model>-pp<P>-mb<B>/
        manifest.toml          # shapes + param counts, parsed by rust/src/config/toml.rs
        <kind>.<fn>.hlo.txt    # kind in {first, mid, last, full}

Functions per stage kind (all lowered with ``return_tuple=True``; the Rust
runtime unpacks the tuple):

    init   (seed i32[])                            -> (flat,)
    fwd    first: (flat, tokens)                   -> (h,)
           mid:   (flat, x)                        -> (h,)
           last:  (flat, x)                        -> (logits,)   [not used on hot path]
    loss   last:  (flat, x, tokens)                -> (loss,)
           full:  (flat, tokens)                   -> (loss,)
    bwd    first: (flat, tokens, g_out)            -> (gflat,)
           mid:   (flat, x, g_out)                 -> (gflat, gx)
           last:  (flat, x, tokens)                -> (loss, gflat, gx)
           full:  (flat, tokens)                   -> (loss, gflat)
    adam   (flat, m, v, g, scalars[6])             -> (flat, m, v)
    outer_noloco (phi, delta, dsum, psum, s[4])    -> (phi, delta)
    outer_diloco (phi, delta, dmean, s[4])         -> (phi, delta)

The CPU-scale presets here mirror ``rust/src/config/presets.rs`` exactly;
``rust/tests/integration.rs`` cross-checks the manifest against the Rust
presets so the two cannot drift silently.
"""

import argparse
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import outer_update

# CPU-scale presets (mirror of rust/src/config/presets.rs). Paper-scale
# presets exist on the Rust side for config/latency math but are never
# lowered here — compiling a 6.8B-parameter stage on a 1-core CPU image is
# not useful.
PRESETS = {
    "tiny": dict(hidden=64, layers=4, intermediate=256, heads=4, vocab=512, seq_len=64),
    "small": dict(hidden=128, layers=4, intermediate=512, heads=4, vocab=1024, seq_len=128),
    "e2e": dict(hidden=256, layers=8, intermediate=1024, heads=8, vocab=4096, seq_len=128),
}

#: Default builds for ``make artifacts``: (preset, pp, microbatch-seqs).
DEFAULT_BUILDS = [
    ("tiny", 1, 2),
    ("tiny", 2, 2),
    ("small", 2, 4),
    ("e2e", 2, 4),
]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_to(path, fn, *args):
    """jit + lower ``fn`` at the given abstract args and write HLO text."""
    text = to_hlo_text(jax.jit(fn).lower(*args))
    with open(path, "w") as f:
        f.write(text)
    return len(text)


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def stage_kinds(pp: int):
    if pp == 1:
        return ["full"]
    if pp == 2:
        return ["first", "last"]
    return ["first", "mid", "last"]


def adam_fn(flat, m, v, g, scalars):
    return model.adam_update(flat, m, v, g, scalars)


def build(preset: str, pp: int, mb: int, out_root: str, use_kernels: bool = True):
    """Lower every artifact for one (preset, pp, mb) build. Returns dir."""
    cfg = dict(PRESETS[preset])
    assert cfg["layers"] % pp == 0, (preset, pp)
    cfg["layers_per_stage"] = cfg["layers"] // pp

    name = f"{preset}-pp{pp}-mb{mb}"
    out_dir = os.path.join(out_root, name)
    os.makedirs(out_dir, exist_ok=True)

    s, h, v = cfg["seq_len"], cfg["hidden"], cfg["vocab"]
    tok = spec((mb, s), jnp.int32)
    hid = spec((mb, s, h))
    kinds = stage_kinds(pp)
    counts = {}
    total_bytes = 0

    for kind in kinds:
        n_params = model.stage_param_count(cfg, kind)
        counts[kind] = n_params
        flat = spec((n_params,))
        p = os.path.join(out_dir, kind)

        # --- init ---
        total_bytes += lower_to(
            f"{p}.init.hlo.txt",
            lambda seed, kind=kind: (model.init_stage_traced(cfg, kind, seed),),
            spec((), jnp.int32),
        )

        # --- forward / loss / backward ---
        if kind == "first":
            total_bytes += lower_to(
                f"{p}.fwd.hlo.txt",
                lambda fl, t: (model.stage_fwd(cfg, "first", fl, t, use_kernels),),
                flat, tok,
            )
            total_bytes += lower_to(
                f"{p}.bwd.hlo.txt",
                lambda fl, t, g: (model.stage_bwd_first(cfg, fl, t, g, use_kernels),),
                flat, tok, hid,
            )
        elif kind == "mid":
            total_bytes += lower_to(
                f"{p}.fwd.hlo.txt",
                lambda fl, x: (model.stage_fwd(cfg, "mid", fl, x, use_kernels),),
                flat, hid,
            )
            total_bytes += lower_to(
                f"{p}.bwd.hlo.txt",
                lambda fl, x, g: model.stage_bwd_mid(cfg, fl, x, g, use_kernels),
                flat, hid, hid,
            )
        elif kind == "last":
            total_bytes += lower_to(
                f"{p}.loss.hlo.txt",
                lambda fl, x, t: (model.stage_loss(cfg, "last", fl, x, t, use_kernels),),
                flat, hid, tok,
            )
            total_bytes += lower_to(
                f"{p}.bwd.hlo.txt",
                lambda fl, x, t: model.stage_bwd_last(cfg, fl, x, t, use_kernels),
                flat, hid, tok,
            )
        else:  # full
            total_bytes += lower_to(
                f"{p}.loss.hlo.txt",
                lambda fl, t: (model.stage_loss(cfg, "full", fl, t, t, use_kernels),),
                flat, tok,
            )
            total_bytes += lower_to(
                f"{p}.bwd.hlo.txt",
                lambda fl, t: model.stage_bwd_full(cfg, fl, t, use_kernels),
                flat, tok,
            )

        # --- optimizer updates on this stage's flat vector ---
        total_bytes += lower_to(
            f"{p}.adam.hlo.txt", adam_fn, flat, flat, flat, flat, spec((6,))
        )
        total_bytes += lower_to(
            f"{p}.outer_noloco.hlo.txt",
            lambda phi, d, ds, ps, sc: outer_update.noloco_outer(phi, d, ds, ps, sc),
            flat, flat, flat, flat, spec((4,)),
        )
        total_bytes += lower_to(
            f"{p}.outer_diloco.hlo.txt",
            lambda phi, d, dm, sc: outer_update.diloco_outer(phi, d, dm, sc),
            flat, flat, flat, spec((4,)),
        )

    write_manifest(out_dir, preset, cfg, pp, mb, counts)
    write_golden(out_dir, cfg, pp, mb)
    return out_dir, total_bytes


def _stat_lines(prefix, arr):
    a = jnp.asarray(arr, jnp.float32).ravel()
    return [
        f"{prefix}_mean = {float(a.mean()):.9e}",
        f"{prefix}_std = {float(a.std()):.9e}",
        f"{prefix}_first = {float(a[0]):.9e}",
        f"{prefix}_last = {float(a[-1]):.9e}",
    ]


def write_golden(out_dir, cfg, pp, mb):
    """Golden values for the Rust runtime's cross-language test.

    The Rust side (rust/tests/runtime_e2e.rs) executes the same artifact
    chain through PJRT with the same deterministic inputs and asserts these
    statistics match — catching interchange bugs (argument order, layout,
    tuple unpacking) that same-language tests cannot see.
    """
    s, v = cfg["seq_len"], cfg["vocab"]
    tokens = (jnp.arange(mb * s, dtype=jnp.int32) * 7919 + 13) % v
    tokens = tokens.reshape(mb, s)

    kinds = stage_kinds(pp)
    lines = [f"# golden values, deterministic tokens = (i*7919+13) % vocab"]
    if pp == 1:
        flat = model.init_stage(cfg, "full", 42)
        lines += _stat_lines("full_init", flat)
        loss, gflat = model.stage_bwd_full(cfg, flat, tokens)
        lines.append(f"loss = {float(loss):.9e}")
        lines += _stat_lines("full_grad", gflat)
        tail = (flat, gflat)
    else:
        first = model.init_stage(cfg, "first", 42)
        last = model.init_stage(cfg, "last", 43)
        lines += _stat_lines("first_init", first)
        lines += _stat_lines("last_init", last)
        h = model.stage_fwd(cfg, "first", first, tokens)
        if "mid" in kinds:
            mid = model.init_stage(cfg, "mid", 44)
            lines += _stat_lines("mid_init", mid)
            h = model.stage_fwd(cfg, "mid", mid, h)
        lines += _stat_lines("hidden", h)
        loss, glast, gx = model.stage_bwd_last(cfg, last, h, tokens)
        lines.append(f"loss = {float(loss):.9e}")
        lines += _stat_lines("last_grad", glast)
        lines += _stat_lines("gx", gx)
        tail = (first, None)

    # Optimizer artifacts on the first-listed stage's vector.
    flat = tail[0]
    g = 0.01 * flat + 0.005
    m = jnp.zeros_like(flat)
    vv = jnp.zeros_like(flat)
    scalars = jnp.array([1e-3, 1.0, 0.9, 0.999, 1e-8, 1.0], jnp.float32)
    f2, m2, v2 = model.adam_update(flat, m, vv, g, scalars)
    lines += _stat_lines("adam_flat", f2)
    lines += _stat_lines("adam_m", m2)

    phi = flat
    delta = 0.001 * flat
    dsum = 0.02 * flat + 0.01
    psum = 2.0 * flat + 0.1
    osc = jnp.array([0.5, 0.7, 0.9, 0.5], jnp.float32)
    phi2, delta2 = outer_update.noloco_outer(phi, delta, dsum, psum, osc)
    lines += _stat_lines("outer_phi", phi2)
    lines += _stat_lines("outer_delta", delta2)

    with open(os.path.join(out_dir, "golden.toml"), "w") as f:
        f.write("\n".join(lines) + "\n")


def write_manifest(out_dir, preset, cfg, pp, mb, counts):
    """Manifest in the TOML subset rust/src/config/toml.rs parses."""
    lines = [
        "# generated by python/compile/aot.py — do not edit",
        "[build]",
        f'model = "{preset}"',
        f"pp = {pp}",
        f"mb = {mb}",
        "[model]",
        f"hidden = {cfg['hidden']}",
        f"layers = {cfg['layers']}",
        f"layers_per_stage = {cfg['layers_per_stage']}",
        f"intermediate = {cfg['intermediate']}",
        f"heads = {cfg['heads']}",
        f"vocab = {cfg['vocab']}",
        f"seq_len = {cfg['seq_len']}",
        "[params]",
    ]
    for kind, n in counts.items():
        lines.append(f"{kind} = {n}")
    with open(os.path.join(out_dir, "manifest.toml"), "w") as f:
        f.write("\n".join(lines) + "\n")


def parse_build(s: str):
    """``preset:pp:mb`` -> tuple."""
    preset, pp, mb = s.split(":")
    return preset, int(pp), int(mb)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.split("\n", 1)[0])
    ap.add_argument("--out-dir", default="../artifacts", help="artifact root")
    ap.add_argument(
        "--build",
        action="append",
        default=None,
        metavar="PRESET:PP:MB",
        help="build spec (repeatable); default: the standard set",
    )
    ap.add_argument(
        "--no-kernels",
        action="store_true",
        help="lower with the pure-jnp reference instead of Pallas kernels "
        "(debugging aid; artifacts are numerically equivalent)",
    )
    args = ap.parse_args(argv)
    builds = [parse_build(b) for b in args.build] if args.build else DEFAULT_BUILDS
    for preset, pp, mb in builds:
        out_dir, nbytes = build(
            preset, pp, mb, args.out_dir, use_kernels=not args.no_kernels
        )
        print(f"built {out_dir} ({nbytes / 1e6:.1f} MB of HLO text)", flush=True)
    # Stamp for the Makefile staleness check.
    with open(os.path.join(args.out_dir, ".stamp"), "w") as f:
        f.write("ok\n")


if __name__ == "__main__":
    sys.exit(main())
