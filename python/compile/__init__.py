"""Build-time compile path: Layer-2 JAX model + Layer-1 Pallas kernels.

Nothing in this package runs at training time — ``aot.py`` lowers the
jitted stage functions to HLO text once (``make artifacts``), and the Rust
coordinator executes the compiled artifacts through PJRT.
"""
