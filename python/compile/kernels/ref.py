"""Pure-jnp oracles for the Pallas kernels.

These are the correctness anchors: ``pytest python/tests`` asserts the
kernels match these references across shape/dtype sweeps (hypothesis), and
the Layer-2 model can be flipped onto the references with
``use_kernels=False`` to isolate kernel bugs from model bugs.
"""

import jax.numpy as jnp


def causal_attention(q, k, v, scale=None):
    """Reference causal attention.

    Args:
      q, k, v: ``[B, H, S, D]`` arrays.
      scale: softmax scale; defaults to ``1/sqrt(D)``.

    Returns:
      ``[B, H, S, D]`` attention output.
    """
    d = q.shape[-1]
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.asarray(d, q.dtype))
    s = q.shape[-2]
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    mask = jnp.tril(jnp.ones((s, s), bool))
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jnp.exp(logits - logits.max(-1, keepdims=True))
    probs = probs / probs.sum(-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v)


def noloco_outer(phi, delta, delta_sum, phi_sum, alpha, beta, gamma, n):
    """Reference NoLoCo modified-Nesterov outer update (Eq. 2-3).

    ``delta_sum``/``phi_sum`` are the *sums* over the gossip group
    (including this replica); ``n`` is the group size. Sign convention per
    the paper's appendix (see rust/src/optim/outer.rs).

    Returns ``(phi_new, delta_new)``.
    """
    delta_new = (
        alpha * delta
        + (beta / n) * delta_sum
        - gamma * (phi - phi_sum / n)
    )
    return phi + delta_new, delta_new


def diloco_outer(phi, delta, delta_mean, alpha, beta):
    """Reference DiLoCo Nesterov outer update (n = world, gamma = 0)."""
    delta_new = alpha * delta + beta * delta_mean
    return phi + delta_new, delta_new
