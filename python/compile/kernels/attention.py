"""Fused causal attention as a Pallas kernel (flash-attention style).

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the CUDA
flash-attention the paper trains with keeps K/V tiles in shared memory and
iterates query blocks per threadblock. The TPU rethink here:

* the grid is ``(batch*heads, q_tiles)`` — each grid step owns one query
  tile resident in VMEM (BlockSpec), the role CUDA gives a threadblock;
* the KV sequence is walked in VMEM-sized tiles with an online-softmax
  carry (running max ``m``, normalizer ``l``, accumulator ``acc``) — warp
  registers in the CUDA version, kernel-local values here;
* both matmuls (``q k^T`` and ``p v``) are expressed so the MXU sees
  ``[bq, d] x [d, bk]`` / ``[bq, bk] x [bk, d]`` contractions with f32
  accumulation (``preferred_element_type``).

``interpret=True`` everywhere: CPU PJRT cannot run Mosaic custom-calls, so
the kernel lowers to plain HLO and the same artifact runs under the Rust
PJRT client. VMEM footprint per grid step is
``bq*d + 2*bk*d + bq*bk + 3*bq`` floats — reported by
:func:`vmem_floats` and tracked in DESIGN.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: Default query-tile length.
DEFAULT_BQ = 32
#: Default key/value-tile length.
DEFAULT_BK = 32

#: Large-negative logit for masked positions (safer than -inf inside the
#: online-softmax recurrence: keeps `m` finite on fully-masked tiles).
NEG_INF = -1e30


def vmem_floats(bq: int, bk: int, d: int) -> int:
    """Floats resident in VMEM per grid step (tiles + carries)."""
    return bq * d + 2 * bk * d + bq * bk + 3 * bq


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, scale, bk, seq_len, q_tile):
    """One grid step: query tile `q_tile` attends over all causal KV tiles."""
    qi = pl.program_id(1)
    q = q_ref[...] * scale  # [bq, d]
    bq = q.shape[0]
    d = q.shape[1]

    q_start = qi * q_tile
    # Causality: KV tiles strictly after this query tile never contribute.
    num_k = (q_start + bq + bk - 1) // bk

    def body(ki, carry):
        acc, m, l = carry
        k = pl.load(k_ref, (pl.ds(ki * bk, bk), slice(None)))  # [bk, d]
        v = pl.load(v_ref, (pl.ds(ki * bk, bk), slice(None)))  # [bk, d]
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # [bq, bk]
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        logits = jnp.where(k_pos <= q_pos, logits, NEG_INF)
        m_new = jnp.maximum(m, logits.max(axis=1))  # [bq]
        p = jnp.exp(logits - m_new[:, None])  # [bq, bk]
        corr = jnp.exp(m - m_new)  # [bq]
        l_new = l * corr + p.sum(axis=1)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )  # [bq, d]
        acc_new = acc * corr[:, None] + pv
        return acc_new, m_new, l_new

    init = (
        jnp.zeros((bq, d), jnp.float32),
        jnp.full((bq,), NEG_INF, jnp.float32),
        jnp.zeros((bq,), jnp.float32),
    )
    acc, _, l = jax.lax.fori_loop(0, num_k, body, init)
    o_ref[...] = (acc / l[:, None]).astype(o_ref.dtype)
    del seq_len  # shape bookkeeping only


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def causal_attention(q, k, v, bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK):
    """Causal attention over ``[B, H, S, D]`` via the Pallas kernel.

    ``S`` must be divisible by both tile sizes (the model picks tiles that
    divide its sequence length).

    Differentiation: the forward is the fused Pallas kernel; the backward
    recomputes attention through the pure-jnp reference under ``jax.vjp``
    (flash-attention-style recompute — no probability matrix is saved
    between passes). On real TPUs the backward would be a second Pallas
    kernel (dq/dk/dv tiles); under interpret-mode CPU lowering both paths
    emit plain HLO, so the XLA-fused reference backward is the faithful
    stand-in. See DESIGN.md §Hardware-Adaptation.
    """
    b, h, s, d = q.shape
    bq = min(bq, s)
    bk = min(bk, s)
    assert s % bq == 0 and s % bk == 0, (s, bq, bk)
    scale = 1.0 / (d**0.5)

    qf = q.reshape(b * h, s, d)
    kf = k.reshape(b * h, s, d)
    vf = v.reshape(b * h, s, d)

    grid = (b * h, s // bq)
    out = pl.pallas_call(
        functools.partial(
            _attn_kernel, scale=scale, bk=bk, seq_len=s, q_tile=bq
        ),
        grid=grid,
        in_specs=[
            # Query tile: one [bq, d] block per grid step.
            pl.BlockSpec((None, bq, d), lambda bh, qi: (bh, qi, 0)),
            # Full K/V for the current head stay resident; the kernel
            # walks them in bk-tiles (VMEM schedule).
            pl.BlockSpec((None, s, d), lambda bh, qi: (bh, 0, 0)),
            pl.BlockSpec((None, s, d), lambda bh, qi: (bh, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, bq, d), lambda bh, qi: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, d), q.dtype),
        interpret=True,
    )(qf, kf, vf)
    return out.reshape(b, h, s, d)


def _causal_attention_fwd(q, k, v, bq, bk):
    return causal_attention(q, k, v, bq, bk), (q, k, v)


def _causal_attention_bwd(bq, bk, res, g):
    del bq, bk
    q, k, v = res
    from . import ref  # local import to avoid a cycle at module load

    _, vjp = jax.vjp(ref.causal_attention, q, k, v)
    return vjp(g)


causal_attention.defvjp(_causal_attention_fwd, _causal_attention_bwd)
