"""Layer-1 Pallas kernels.

Two kernels back the paper's compute hot-spots:

* :mod:`.attention` — fused causal attention with online softmax
  (flash-attention style; §4 of the paper trains with flash-attention).
* :mod:`.outer_update` — the fused NoLoCo modified-Nesterov outer step
  (Eq. 2-3), one elementwise pass producing (phi', delta').

Both run under ``interpret=True`` (the CPU PJRT plugin cannot execute
Mosaic custom-calls); kernel *structure* is TPU-shaped — BlockSpec tiling
expresses the HBM->VMEM schedule. Correctness oracles live in
:mod:`.ref`.
"""

from . import attention, outer_update, ref  # noqa: F401
