"""Fused NoLoCo outer update as a Pallas kernel (Eq. 2-3).

One elementwise pass over the flattened parameter vector computes

```
delta' = alpha*delta + (beta/n)*sum_j Delta_j - gamma*(phi - (1/n) sum_j phi_j)
phi'   = phi + delta'
```

Fusing the five reads and two writes matters because the outer step runs
over the *entire* replica state (every parameter) and is memory-bound: the
naive jnp expression materializes three temporaries; this kernel streams
each VMEM tile exactly once. Scalars (alpha, beta, gamma, 1/n) arrive via
scalar prefetch so one compiled artifact serves any hyper-parameter
setting.

TPU shape: grid over 1-D tiles of ``BLOCK`` floats; BlockSpec moves one
tile of each operand HBM->VMEM per step (double-buffered by the compiler
on real hardware). ``interpret=True`` for CPU-PJRT execution.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: Tile length in elements (f32). 6 streams (4 in + 2 out) x 256 KiB
#: tiles = 1.5 MiB of VMEM per grid step — comfortably inside a 16 MiB
#: budget with room for double-buffering. Perf note (EXPERIMENTS.md
#: §Perf): under interpret-mode CPU lowering each grid step carries fixed
#: emulation overhead, so going 4096 -> 65536 cut the tiny-model outer
#: artifact latency ~5x while keeping multi-tile grids for real stages
#: (tiny.first = 164k params = 3 tiles). Tests sweep the block-boundary
#: cases explicitly.
BLOCK = 65536


def _outer_kernel(scalars_ref, phi_ref, delta_ref, dsum_ref, psum_ref,
                  phi_out_ref, delta_out_ref):
    """One tile of the fused update. ``scalars = [alpha, beta, gamma, inv_n]``."""
    alpha = scalars_ref[0]
    beta = scalars_ref[1]
    gamma = scalars_ref[2]
    inv_n = scalars_ref[3]
    phi = phi_ref[...]
    delta_new = (
        alpha * delta_ref[...]
        + (beta * inv_n) * dsum_ref[...]
        - gamma * (phi - inv_n * psum_ref[...])
    )
    delta_out_ref[...] = delta_new
    phi_out_ref[...] = phi + delta_new


@functools.partial(jax.jit, static_argnames=("block",))
def noloco_outer(phi, delta, delta_sum, phi_sum, scalars, block: int = BLOCK):
    """Fused outer update over flat f32 vectors.

    Args:
      phi, delta, delta_sum, phi_sum: ``[L]`` f32 — slow weights, momentum,
        group-sum of outer gradients, group-sum of slow weights.
      scalars: ``[4]`` f32 — ``[alpha, beta, gamma, 1/n]``.
      block: tile length.

    Returns:
      ``(phi_new, delta_new)``, both ``[L]``.
    """
    (n,) = phi.shape
    block = min(block, n)
    pad = (-n) % block
    if pad:
        z = jnp.zeros((pad,), phi.dtype)
        phi_p = jnp.concatenate([phi, z])
        delta_p = jnp.concatenate([delta, z])
        dsum_p = jnp.concatenate([delta_sum, z])
        psum_p = jnp.concatenate([phi_sum, z])
    else:
        phi_p, delta_p, dsum_p, psum_p = phi, delta, delta_sum, phi_sum
    total = n + pad
    grid = (total // block,)
    tile = pl.BlockSpec((block,), lambda i: (i,))
    phi_new, delta_new = pl.pallas_call(
        _outer_kernel,
        grid=grid,
        in_specs=[
            # Scalars replicated to every grid step.
            pl.BlockSpec((4,), lambda i: (0,)),
            tile, tile, tile, tile,
        ],
        out_specs=(tile, tile),
        out_shape=(
            jax.ShapeDtypeStruct((total,), phi.dtype),
            jax.ShapeDtypeStruct((total,), phi.dtype),
        ),
        interpret=True,
    )(scalars, phi_p, delta_p, dsum_p, psum_p)
    return phi_new[:n], delta_new[:n]


@jax.jit
def diloco_outer(phi, delta, delta_mean, scalars):
    """DiLoCo Nesterov outer update on flat vectors.

    ``scalars = [alpha, beta]``. Reuses the fused kernel with
    ``gamma = 0`` and the group mean passed as a size-1 "sum".
    """
    four = jnp.stack([scalars[0], scalars[1], jnp.float32(0.0), jnp.float32(1.0)])
    return noloco_outer(phi, delta, delta_mean, phi, four)
