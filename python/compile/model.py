"""Layer-2: staged Llama-style transformer in JAX.

The model is written *stage-first*: the unit of compilation is one
pipeline stage, because the Rust coordinator owns the pipeline (§3.1
random routing happens between stage executions, outside XLA). Stage
kinds:

* ``first`` — token embedding + ``layers_per_stage`` decoder layers
* ``mid``   — ``layers_per_stage`` decoder layers (reused for every
  interior stage; all interior stages share one artifact)
* ``last``  — ``layers_per_stage`` layers + final RMSNorm + LM head +
  shifted softmax cross-entropy
* ``full``  — the whole model in one stage (pp = 1 runs)

Every stage function takes the stage's parameters as ONE flat f32 vector
(the wire/optimizer format of the Rust side) and unflattens with static
slices — XLA folds these away. Backward passes are recompute-based
(``jax.vjp`` over the stage forward), so no activation stash crosses the
Rust<->XLA boundary; this is the deliberate per-stage rematerialization
noted in DESIGN.md §Perf.

Architecture: RMSNorm -> RoPE causal attention (Layer-1 Pallas kernel) ->
residual -> RMSNorm -> SwiGLU MLP -> residual. Decoder conventions follow
Llama; hyper-parameters come from rust/src/config presets (Table 1).
"""

import functools

import jax
import jax.numpy as jnp

from .kernels import attention as attn_kernel
from .kernels import ref as kernels_ref

STAGE_KINDS = ("first", "mid", "last", "full")


# ---------------------------------------------------------------------------
# Parameter bookkeeping (flat vector <-> named tensors)
# ---------------------------------------------------------------------------

def layer_shapes(cfg):
    """Ordered (name, shape) for one decoder layer."""
    h, i = cfg["hidden"], cfg["intermediate"]
    return [
        ("attn_norm", (h,)),
        ("wq", (h, h)),
        ("wk", (h, h)),
        ("wv", (h, h)),
        ("wo", (h, h)),
        ("mlp_norm", (h,)),
        ("w_gate", (h, i)),
        ("w_up", (h, i)),
        ("w_down", (i, h)),
    ]


def stage_shapes(cfg, kind):
    """Ordered (name, shape) list for a stage kind."""
    assert kind in STAGE_KINDS, kind
    h, v = cfg["hidden"], cfg["vocab"]
    n_layers = cfg["layers"] if kind == "full" else cfg["layers_per_stage"]
    shapes = []
    if kind in ("first", "full"):
        shapes.append(("embed", (v, h)))
    for li in range(n_layers):
        shapes += [(f"l{li}.{n}", s) for n, s in layer_shapes(cfg)]
    if kind in ("last", "full"):
        shapes.append(("final_norm", (h,)))
        shapes.append(("head", (h, v)))
    return shapes


def stage_param_count(cfg, kind):
    """Total scalar parameter count of a stage."""
    return sum(int(jnp.prod(jnp.array(s))) for _, s in stage_shapes(cfg, kind))


def unflatten(flat, shapes):
    """Static-slice a flat vector into a {name: array} dict."""
    out = {}
    off = 0
    for name, shape in shapes:
        n = 1
        for d in shape:
            n *= d
        out[name] = flat[off:off + n].reshape(shape)
        off += n
    assert off == flat.shape[0], (off, flat.shape)
    return out


def init_stage(cfg, kind, seed):
    """Initialize a stage's flat parameter vector (GPT-2-style scaled
    normal init; all replicas share this, matching phi_{0,i} = phi_0)."""
    return init_stage_traced(cfg, kind, jnp.int32(seed))


def init_stage_traced(cfg, kind, seed):
    """[`init_stage`] with a traced i32 seed — the AOT-lowered form, so
    parameter initialization also runs through XLA on the Rust side."""
    key = jax.random.key(seed)
    parts = []
    for i, (name, shape) in enumerate(stage_shapes(cfg, kind)):
        k = jax.random.fold_in(key, i)
        if name.endswith("norm"):
            parts.append(jnp.ones(shape, jnp.float32).ravel())
        else:
            std = 0.02 if name in ("embed", "head") else (2.0 / (shape[0] + shape[-1])) ** 0.5
            # Residual-output projections get the depth-scaled init.
            if name.endswith(("wo", "w_down")):
                std = std / (2.0 * cfg["layers"]) ** 0.5
            parts.append((jax.random.normal(k, shape, jnp.float32) * std).ravel())
    return jnp.concatenate(parts)


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def rms_norm(x, w, eps=1e-5):
    """RMSNorm."""
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * w


def rope(x):
    """Rotary position embedding over ``[B, H, S, D]`` (D even)."""
    b, h, s, d = x.shape
    half = d // 2
    pos = jnp.arange(s, dtype=jnp.float32)[:, None]
    inv = 1.0 / (10000.0 ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = pos * inv  # [S, half]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def decoder_layer(p, x, cfg, use_kernels):
    """One pre-norm decoder layer. ``x``: [B, S, H]."""
    bsz, s, h = x.shape
    nh = cfg["heads"]
    hd = h // nh

    y = rms_norm(x, p["attn_norm"])
    q = (y @ p["wq"]).reshape(bsz, s, nh, hd).transpose(0, 2, 1, 3)
    k = (y @ p["wk"]).reshape(bsz, s, nh, hd).transpose(0, 2, 1, 3)
    v = (y @ p["wv"]).reshape(bsz, s, nh, hd).transpose(0, 2, 1, 3)
    q, k = rope(q), rope(k)
    if use_kernels:
        o = attn_kernel.causal_attention(q, k, v)
    else:
        o = kernels_ref.causal_attention(q, k, v)
    o = o.transpose(0, 2, 1, 3).reshape(bsz, s, h)
    x = x + o @ p["wo"]

    y = rms_norm(x, p["mlp_norm"])
    gate = jax.nn.silu(y @ p["w_gate"])
    x = x + (gate * (y @ p["w_up"])) @ p["w_down"]
    return x


# ---------------------------------------------------------------------------
# Stage forwards
# ---------------------------------------------------------------------------

def stage_fwd(cfg, kind, flat, x, use_kernels=True):
    """Forward one stage.

    ``first``/``full`` take int32 tokens ``[B, S]``; others take hidden
    states ``[B, S, H]``. ``last`` and ``full`` return logits ``[B, S, V]``;
    others return hidden states.
    """
    p = unflatten(flat, stage_shapes(cfg, kind))
    n_layers = cfg["layers"] if kind == "full" else cfg["layers_per_stage"]
    if kind in ("first", "full"):
        x = p["embed"][x]
    for li in range(n_layers):
        lp = {n.split(".", 1)[1]: p[n] for n in p if n.startswith(f"l{li}.")}
        x = decoder_layer(lp, x, cfg, use_kernels)
    if kind in ("last", "full"):
        x = rms_norm(x, p["final_norm"])
        x = x @ p["head"]
    return x


def shifted_ce_loss(logits, tokens):
    """Mean next-token cross-entropy in nats.

    ``logits``: [B, S, V]; ``tokens``: [B, S]. Position t predicts token
    t+1; the final position has no target.
    """
    lg = logits[:, :-1]
    tg = tokens[:, 1:]
    logz = jax.nn.logsumexp(lg, axis=-1)
    picked = jnp.take_along_axis(lg, tg[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - picked)


def stage_loss(cfg, kind, flat, x, tokens, use_kernels=True):
    """Stage forward + loss (``last`` / ``full`` kinds only)."""
    logits = stage_fwd(cfg, kind, flat, x, use_kernels)
    return shifted_ce_loss(logits, tokens)


# ---------------------------------------------------------------------------
# Stage backwards (recompute-based)
# ---------------------------------------------------------------------------

def stage_bwd_first(cfg, flat, tokens, g_out, use_kernels=True):
    """Backward the first stage: returns flat param grads."""
    f = lambda fl: stage_fwd(cfg, "first", fl, tokens, use_kernels)
    _, vjp = jax.vjp(f, flat)
    (gflat,) = vjp(g_out)
    return gflat


def stage_bwd_mid(cfg, flat, x_in, g_out, use_kernels=True):
    """Backward an interior stage: returns (flat param grads, g_in)."""
    f = lambda fl, x: stage_fwd(cfg, "mid", fl, x, use_kernels)
    _, vjp = jax.vjp(f, flat, x_in)
    gflat, gx = vjp(g_out)
    return gflat, gx


def stage_bwd_last(cfg, flat, x_in, tokens, use_kernels=True):
    """Backward the last stage: returns (loss, flat param grads, g_in)."""
    f = lambda fl, x: stage_loss(cfg, "last", fl, x, tokens, use_kernels)
    loss, vjp = jax.vjp(f, flat, x_in)
    gflat, gx = vjp(jnp.float32(1.0))
    return loss, gflat, gx


def stage_bwd_full(cfg, flat, tokens, use_kernels=True):
    """Backward the pp=1 full model: returns (loss, flat param grads)."""
    f = lambda fl: stage_loss(cfg, "full", fl, tokens, tokens, use_kernels)
    loss, vjp = jax.vjp(f, flat)
    (gflat,) = vjp(jnp.float32(1.0))
    return loss, gflat


# ---------------------------------------------------------------------------
# Optimizer updates on flat vectors
# ---------------------------------------------------------------------------

def adam_update(flat, m, v, g, scalars):
    """Adam with bias correction on flat vectors.

    ``scalars``: [lr, t, beta1, beta2, eps, clip] — ``t`` the 1-based step
    as f32; ``clip`` a global-norm threshold applied to ``g`` first
    (paper: 1.0; pass a huge value to disable).
    """
    lr, t, b1, b2, eps, clip = (scalars[i] for i in range(6))
    norm = jnp.sqrt(jnp.sum(g * g))
    g = g * jnp.minimum(1.0, clip / jnp.maximum(norm, 1e-12))
    m_new = b1 * m + (1.0 - b1) * g
    v_new = b2 * v + (1.0 - b2) * g * g
    mhat = m_new / (1.0 - b1**t)
    vhat = v_new / (1.0 - b2**t)
    flat_new = flat - lr * mhat / (jnp.sqrt(vhat) + eps)
    return flat_new, m_new, v_new
