"""Shared pytest config: make `compile` importable and pin JAX to CPU."""

import os
import sys

os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
