"""AOT pipeline tests: lowering produces loadable, well-formed HLO text.

These validate the build-time half of the Rust<->XLA bridge without
needing the Rust binary: HLO text must parse back through xla_client, have
the declared entry signature, and the manifest must agree with the model's
parameter bookkeeping.
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model

CFG_SMALL = dict(aot.PRESETS["tiny"])


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    root = tmp_path_factory.mktemp("artifacts")
    out_dir, _ = aot.build("tiny", 2, 2, str(root))
    return out_dir


class TestBuild:
    def test_produces_expected_files(self, built):
        files = sorted(os.listdir(built))
        assert "manifest.toml" in files
        for kind in ("first", "last"):
            for fn in ("init", "bwd", "adam", "outer_noloco", "outer_diloco"):
                assert f"{kind}.{fn}.hlo.txt" in files, (kind, fn)
        assert "first.fwd.hlo.txt" in files
        assert "last.loss.hlo.txt" in files
        # mid stages only exist for pp >= 3
        assert not any(f.startswith("mid.") for f in files)

    def test_hlo_text_is_wellformed(self, built):
        for f in os.listdir(built):
            if not f.endswith(".hlo.txt"):
                continue
            text = open(os.path.join(built, f)).read()
            assert text.startswith("HloModule"), f
            assert "ENTRY" in text, f

    def test_hlo_text_reparses(self, built):
        # Round-trip through the HLO parser (what the Rust loader does).
        from jax._src.lib import xla_client as xc

        path = os.path.join(built, "first.fwd.hlo.txt")
        text = open(path).read()
        comp = xc._xla.hlo_module_from_text(text)
        assert comp is not None

    def test_manifest_matches_model_counts(self, built):
        cfg = dict(CFG_SMALL, layers_per_stage=CFG_SMALL["layers"] // 2)
        manifest = open(os.path.join(built, "manifest.toml")).read()
        for kind in ("first", "last"):
            n = model.stage_param_count(cfg, kind)
            assert f"{kind} = {n}" in manifest
        assert 'model = "tiny"' in manifest
        assert "pp = 2" in manifest
        assert "mb = 2" in manifest

    def test_stage_kinds_by_pp(self):
        assert aot.stage_kinds(1) == ["full"]
        assert aot.stage_kinds(2) == ["first", "last"]
        assert aot.stage_kinds(4) == ["first", "mid", "last"]

    def test_default_builds_are_valid(self):
        for preset, pp, mb in aot.DEFAULT_BUILDS:
            assert preset in aot.PRESETS
            assert aot.PRESETS[preset]["layers"] % pp == 0
            assert mb >= 1

    def test_parse_build(self):
        assert aot.parse_build("e2e:2:4") == ("e2e", 2, 4)
        with pytest.raises(ValueError):
            aot.parse_build("e2e:2")


class TestGolden:
    """The golden.toml emitted next to each build is the cross-language
    contract: rust/tests/runtime_e2e.rs re-derives the same statistics by
    executing the artifacts through PJRT. Here we verify the golden file
    itself is complete, parseable, and self-consistent with eager JAX."""

    def _parse(self, built):
        vals = {}
        for line in open(os.path.join(built, "golden.toml")):
            line = line.strip()
            if not line or line.startswith("#"):
                continue
            k, v = line.split(" = ")
            vals[k] = float(v)
        return vals

    def test_golden_complete(self, built):
        vals = self._parse(built)
        for key in (
            "first_init_mean", "last_init_mean", "hidden_std", "loss",
            "last_grad_std", "gx_std", "adam_flat_mean", "outer_phi_mean",
        ):
            assert key in vals, key
        assert all(np.isfinite(v) for v in vals.values())

    def test_golden_loss_matches_eager_recompute(self, built):
        cfg = dict(CFG_SMALL, layers_per_stage=CFG_SMALL["layers"] // 2)
        vals = self._parse(built)
        s, v = cfg["seq_len"], cfg["vocab"]
        tokens = ((jnp.arange(2 * s, dtype=jnp.int32) * 7919 + 13) % v).reshape(2, s)
        first = model.init_stage(cfg, "first", 42)
        last = model.init_stage(cfg, "last", 43)
        h = model.stage_fwd(cfg, "first", first, tokens)
        loss = model.stage_loss(cfg, "last", last, h, tokens)
        np.testing.assert_allclose(vals["loss"], float(loss), rtol=1e-6)
        # An untrained model's loss should be near log(vocab).
        assert abs(vals["loss"] - np.log(v)) < 1.0

    def test_golden_init_stats_sane(self, built):
        vals = self._parse(built)
        # Init vectors are mostly small-normal weights plus ones for norms:
        # mean slightly positive, std well below 1.
        assert 0.0 < vals["first_init_std"] < 0.2
        assert 0.0 < vals["last_init_std"] < 0.2
