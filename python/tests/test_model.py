"""Layer-2 model correctness: stage composition, backward passes, init.

The critical invariant: running the pipeline stages in sequence (the way
the Rust coordinator does) is numerically identical to the single ``full``
stage, both forward and backward. If this holds, pipeline parallelism
cannot change the optimization trajectory — only the routing/outer steps
can, which is exactly the paper's claim structure.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model

CFG = dict(
    hidden=32, layers=2, intermediate=64, heads=2, vocab=64, seq_len=16,
    layers_per_stage=1,
)
MB = 2


@pytest.fixture(scope="module")
def toks():
    return jax.random.randint(jax.random.key(0), (MB, CFG["seq_len"]), 0, CFG["vocab"])


@pytest.fixture(scope="module")
def stage_params():
    # first/last stages initialized from one seed each; full re-assembled
    # from the same two so composition comparisons are exact.
    first = model.init_stage(CFG, "first", 1)
    last = model.init_stage(CFG, "last", 2)
    return first, last


def full_from_stages(first, last):
    """Splice first+last stage vectors into one 'full' vector (pp=1
    layout: embed, layers 0..L-1, final_norm, head)."""
    shapes_first = model.stage_shapes(CFG, "first")
    p_first = model.unflatten(first, shapes_first)
    p_last = model.unflatten(last, model.stage_shapes(CFG, "last"))
    parts = [p_first["embed"].ravel()]
    for n, _ in model.layer_shapes(CFG):
        parts.append(p_first[f"l0.{n}"].ravel())
    for n, _ in model.layer_shapes(CFG):
        parts.append(p_last[f"l0.{n}"].ravel())
    parts += [p_last["final_norm"].ravel(), p_last["head"].ravel()]
    return jnp.concatenate(parts)


class TestShapes:
    def test_stage_param_counts(self):
        h, i, v = CFG["hidden"], CFG["intermediate"], CFG["vocab"]
        per_layer = 4 * h * h + 3 * h * i + 2 * h
        assert model.stage_param_count(CFG, "first") == v * h + per_layer
        assert model.stage_param_count(CFG, "mid") == per_layer
        assert model.stage_param_count(CFG, "last") == per_layer + h + h * v
        assert model.stage_param_count(CFG, "full") == (
            v * h + 2 * per_layer + h + h * v
        )

    def test_unflatten_roundtrip(self):
        shapes = model.stage_shapes(CFG, "last")
        n = model.stage_param_count(CFG, "last")
        flat = jnp.arange(n, dtype=jnp.float32)
        parts = model.unflatten(flat, shapes)
        rebuilt = jnp.concatenate([parts[name].ravel() for name, _ in shapes])
        np.testing.assert_array_equal(flat, rebuilt)

    def test_fwd_output_shapes(self, toks, stage_params):
        first, last = stage_params
        h = model.stage_fwd(CFG, "first", first, toks)
        assert h.shape == (MB, CFG["seq_len"], CFG["hidden"])
        logits = model.stage_fwd(CFG, "last", last, h)
        assert logits.shape == (MB, CFG["seq_len"], CFG["vocab"])


class TestInit:
    def test_deterministic(self):
        a = model.init_stage(CFG, "first", 7)
        b = model.init_stage(CFG, "first", 7)
        np.testing.assert_array_equal(a, b)
        c = model.init_stage(CFG, "first", 8)
        assert not np.array_equal(a, c)

    def test_traced_matches_eager(self):
        eager = model.init_stage(CFG, "last", 3)
        traced = jax.jit(lambda s: model.init_stage_traced(CFG, "last", s))(
            jnp.int32(3)
        )
        # jit fuses the scale multiply differently -> 1-ulp differences.
        np.testing.assert_allclose(eager, traced, rtol=1e-6, atol=1e-7)

    def test_norm_weights_are_ones(self):
        flat = model.init_stage(CFG, "last", 0)
        p = model.unflatten(flat, model.stage_shapes(CFG, "last"))
        np.testing.assert_array_equal(p["final_norm"], jnp.ones(CFG["hidden"]))
        np.testing.assert_array_equal(p["l0.attn_norm"], jnp.ones(CFG["hidden"]))

    def test_init_scale_sane(self):
        flat = model.init_stage(CFG, "first", 0)
        p = model.unflatten(flat, model.stage_shapes(CFG, "first"))
        assert abs(float(p["embed"].std()) - 0.02) < 0.005
        # Residual projections get depth-scaled (smaller) init.
        assert float(p["l0.wo"].std()) < float(p["l0.wq"].std())


class TestComposition:
    def test_staged_forward_equals_full(self, toks, stage_params):
        first, last = stage_params
        h = model.stage_fwd(CFG, "first", first, toks)
        staged_logits = model.stage_fwd(CFG, "last", last, h)
        full_cfg = dict(CFG)
        full = full_from_stages(first, last)
        full_logits = model.stage_fwd(full_cfg, "full", full, toks)
        np.testing.assert_allclose(staged_logits, full_logits, rtol=1e-5, atol=1e-5)

    def test_staged_loss_equals_full(self, toks, stage_params):
        first, last = stage_params
        h = model.stage_fwd(CFG, "first", first, toks)
        staged = model.stage_loss(CFG, "last", last, h, toks)
        full = model.stage_loss(dict(CFG), "full", full_from_stages(first, last), toks, toks)
        np.testing.assert_allclose(staged, full, rtol=1e-5, atol=1e-6)

    def test_staged_backward_equals_full(self, toks, stage_params):
        # Chain rule across the Rust-managed boundary: bwd_last produces
        # g_in, bwd_first consumes it; the concatenated grads must equal
        # grads of the full model.
        first, last = stage_params
        h = model.stage_fwd(CFG, "first", first, toks)
        loss, g_last, gx = model.stage_bwd_last(CFG, last, h, toks)
        g_first = model.stage_bwd_first(CFG, first, toks, gx)

        full = full_from_stages(first, last)
        loss_full, g_full = model.stage_bwd_full(dict(CFG), full, toks)
        np.testing.assert_allclose(loss, loss_full, rtol=1e-5, atol=1e-6)
        g_staged_full = full_from_stages(g_first, g_last)
        np.testing.assert_allclose(g_staged_full, g_full, rtol=2e-4, atol=2e-5)

    def test_mid_stage_chain(self, toks):
        # 3-stage chain (first -> mid -> last) forward+backward shape sanity
        # and finite gradients.
        cfg = dict(CFG)
        first = model.init_stage(cfg, "first", 1)
        mid = model.init_stage(cfg, "mid", 2)
        last = model.init_stage(cfg, "last", 3)
        h1 = model.stage_fwd(cfg, "first", first, toks)
        h2 = model.stage_fwd(cfg, "mid", mid, h1)
        loss, g_last, gx2 = model.stage_bwd_last(cfg, last, h2, toks)
        g_mid, gx1 = model.stage_bwd_mid(cfg, mid, h1, gx2)
        g_first = model.stage_bwd_first(cfg, first, toks, gx1)
        assert g_mid.shape == mid.shape and g_first.shape == first.shape
        for g in (g_last, g_mid, g_first, gx1, gx2):
            assert bool(jnp.isfinite(g).all())
        assert float(loss) > 0.0

    def test_kernel_vs_reference_model(self, toks, stage_params):
        # The whole stage with Pallas attention vs reference attention.
        first, _ = stage_params
        a = model.stage_fwd(CFG, "first", first, toks, use_kernels=True)
        b = model.stage_fwd(CFG, "first", first, toks, use_kernels=False)
        np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-5)


class TestLoss:
    def test_uniform_logits_loss_is_log_vocab(self, toks):
        logits = jnp.zeros((MB, CFG["seq_len"], CFG["vocab"]), jnp.float32)
        loss = model.shifted_ce_loss(logits, toks)
        np.testing.assert_allclose(loss, np.log(CFG["vocab"]), rtol=1e-6)

    def test_perfect_prediction_loss_near_zero(self, toks):
        # Put huge mass on the true next token.
        v = CFG["vocab"]
        onehot = jax.nn.one_hot(toks, v) * 100.0
        # logits at position t should predict token t+1
        logits = jnp.roll(onehot, -1, axis=1)
        loss = model.shifted_ce_loss(logits, toks)
        assert float(loss) < 1e-3

    def test_shift_excludes_last_position(self, toks):
        # Perturbing the logits at the final position must not change loss.
        logits = jax.random.normal(jax.random.key(1), (MB, CFG["seq_len"], CFG["vocab"]))
        l1 = model.shifted_ce_loss(logits, toks)
        l2 = model.shifted_ce_loss(logits.at[:, -1].add(123.0), toks)
        np.testing.assert_allclose(l1, l2, rtol=1e-6)

    def test_gradient_through_loss_finite(self, toks, stage_params):
        first, last = stage_params
        h = model.stage_fwd(CFG, "first", first, toks)
        g = jax.grad(lambda fl: model.stage_loss(CFG, "last", fl, h, toks))(last)
        assert bool(jnp.isfinite(g).all())
        assert float(jnp.abs(g).max()) > 0.0


class TestAdam:
    def test_matches_reference_adam(self):
        n = 257
        key = jax.random.key(0)
        ks = jax.random.split(key, 4)
        flat, m, v, g = (jax.random.normal(k, (n,)) for k in ks)
        m, v = m * 0.01, jnp.abs(v) * 0.01
        lr, t, b1, b2, eps, clip = 1e-3, 3.0, 0.9, 0.999, 1e-8, 1e9
        scalars = jnp.array([lr, t, b1, b2, eps, clip], jnp.float32)
        f2, m2, v2 = model.adam_update(flat, m, v, g, scalars)
        # reference
        m_ref = b1 * m + (1 - b1) * g
        v_ref = b2 * v + (1 - b2) * g * g
        mhat = m_ref / (1 - b1**t)
        vhat = v_ref / (1 - b2**t)
        f_ref = flat - lr * mhat / (jnp.sqrt(vhat) + eps)
        np.testing.assert_allclose(f2, f_ref, rtol=1e-6, atol=1e-7)
        np.testing.assert_allclose(m2, m_ref, rtol=1e-6, atol=1e-7)
        np.testing.assert_allclose(v2, v_ref, rtol=1e-6, atol=1e-7)

    def test_clip_applies_before_moments(self):
        n = 16
        g = jnp.full((n,), 10.0)  # norm = 40
        flat = jnp.zeros((n,))
        m = jnp.zeros((n,))
        v = jnp.zeros((n,))
        scalars = jnp.array([1e-3, 1.0, 0.9, 0.999, 1e-8, 1.0], jnp.float32)
        _, m2, _ = model.adam_update(flat, m, v, g, scalars)
        # clipped g has norm 1 -> each element 1/4 -> m = 0.1 * 0.25
        np.testing.assert_allclose(m2, jnp.full((n,), 0.025), rtol=1e-5)

    def test_descends_quadratic(self):
        # 200 Adam steps on f(x) = ||x||^2 must shrink the norm a lot.
        n = 32
        x = jax.random.normal(jax.random.key(1), (n,))
        m = jnp.zeros_like(x)
        v = jnp.zeros_like(x)
        for t in range(1, 201):
            g = 2 * x
            scalars = jnp.array([0.05, float(t), 0.9, 0.999, 1e-8, 1e9], jnp.float32)
            x, m, v = model.adam_update(x, m, v, g, scalars)
        assert float(jnp.linalg.norm(x)) < 0.05
