"""Layer-1 kernel correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes/dtypes/tile sizes; every case asserts allclose
against ``kernels.ref``. These tests are the numerical anchor for the whole
stack — the AOT artifacts embed exactly these kernels.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention, outer_update, ref

jax.config.update("jax_enable_x64", False)


def rand(key, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Fused causal attention
# ---------------------------------------------------------------------------

class TestCausalAttention:
    @settings(max_examples=25, deadline=None)
    @given(
        b=st.integers(1, 3),
        h=st.integers(1, 4),
        s_tiles=st.integers(1, 4),
        d=st.sampled_from([4, 8, 16, 32]),
        bq=st.sampled_from([8, 16, 32]),
        bk=st.sampled_from([8, 16, 32]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_reference_across_shapes(self, b, h, s_tiles, d, bq, bk, seed):
        # S is a multiple of both tile sizes (model contract).
        s = s_tiles * max(bq, bk)
        key = jax.random.key(seed)
        kq, kk, kv = jax.random.split(key, 3)
        q, k, v = rand(kq, (b, h, s, d)), rand(kk, (b, h, s, d)), rand(kv, (b, h, s, d))
        got = attention.causal_attention(q, k, v, bq, bk)
        want = ref.causal_attention(q, k, v)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)

    @settings(max_examples=10, deadline=None)
    @given(
        s=st.sampled_from([32, 64]),
        d=st.sampled_from([8, 16]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_gradients_match_reference(self, s, d, seed):
        key = jax.random.key(seed)
        kq, kk, kv, kg = jax.random.split(key, 4)
        q, k, v = rand(kq, (1, 2, s, d)), rand(kk, (1, 2, s, d)), rand(kv, (1, 2, s, d))
        g = rand(kg, (1, 2, s, d))

        def loss_kernel(q, k, v):
            return jnp.sum(attention.causal_attention(q, k, v) * g)

        def loss_ref(q, k, v):
            return jnp.sum(ref.causal_attention(q, k, v) * g)

        gk = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
        gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
        for a, b_ in zip(gk, gr):
            np.testing.assert_allclose(a, b_, rtol=1e-4, atol=1e-4)

    def test_causality_no_future_leakage(self):
        # Perturbing position t must not change outputs at positions < t.
        key = jax.random.key(0)
        kq, kk, kv = jax.random.split(key, 3)
        s, d = 64, 16
        q, k, v = rand(kq, (1, 1, s, d)), rand(kk, (1, 1, s, d)), rand(kv, (1, 1, s, d))
        base = attention.causal_attention(q, k, v)
        t = 40
        k2 = k.at[:, :, t:].add(100.0)
        v2 = v.at[:, :, t:].add(-50.0)
        pert = attention.causal_attention(q, k2, v2)
        np.testing.assert_allclose(base[:, :, :t], pert[:, :, :t], rtol=1e-6, atol=1e-6)
        # ... and must change something at/after t.
        assert not np.allclose(base[:, :, t:], pert[:, :, t:])

    def test_first_position_attends_only_itself(self):
        key = jax.random.key(1)
        kq, kk, kv = jax.random.split(key, 3)
        s, d = 32, 8
        q, k, v = rand(kq, (1, 1, s, d)), rand(kk, (1, 1, s, d)), rand(kv, (1, 1, s, d))
        out = attention.causal_attention(q, k, v)
        np.testing.assert_allclose(out[0, 0, 0], v[0, 0, 0], rtol=1e-5, atol=1e-5)

    def test_uniform_values_are_preserved(self):
        # If V is constant, softmax-weighted averages equal that constant.
        s, d = 64, 16
        key = jax.random.key(2)
        kq, kk = jax.random.split(key)
        q, k = rand(kq, (2, 2, s, d)), rand(kk, (2, 2, s, d))
        v = jnp.full((2, 2, s, d), 3.25, jnp.float32)
        out = attention.causal_attention(q, k, v)
        np.testing.assert_allclose(out, v, rtol=1e-5, atol=1e-5)

    def test_large_logits_stay_finite(self):
        # Online softmax must not overflow with huge logits.
        s, d = 32, 8
        q = jnp.full((1, 1, s, d), 30.0, jnp.float32)
        k = jnp.full((1, 1, s, d), 30.0, jnp.float32)
        v = rand(jax.random.key(3), (1, 1, s, d))
        out = attention.causal_attention(q, k, v)
        assert bool(jnp.isfinite(out).all())

    def test_tile_sizes_do_not_change_result(self):
        s, d = 64, 16
        key = jax.random.key(4)
        kq, kk, kv = jax.random.split(key, 3)
        q, k, v = rand(kq, (1, 2, s, d)), rand(kk, (1, 2, s, d)), rand(kv, (1, 2, s, d))
        a = attention.causal_attention(q, k, v, 16, 16)
        b = attention.causal_attention(q, k, v, 32, 8)
        c = attention.causal_attention(q, k, v, 64, 64)
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(a, c, rtol=1e-5, atol=1e-5)

    def test_vmem_budget_for_paper_shapes(self):
        # DESIGN.md §Perf: default tiles stay far under a 16 MiB VMEM budget
        # even at the paper's head dim (128).
        floats = attention.vmem_floats(attention.DEFAULT_BQ, attention.DEFAULT_BK, 128)
        assert floats * 4 < 16 * 2**20


# ---------------------------------------------------------------------------
# Fused NoLoCo / DiLoCo outer updates
# ---------------------------------------------------------------------------

class TestOuterUpdate:
    @settings(max_examples=40, deadline=None)
    @given(
        n_elems=st.integers(1, 3 * outer_update.BLOCK + 7),
        alpha=st.floats(0.0, 0.95),
        beta=st.floats(0.05, 1.0),
        gamma=st.floats(0.0, 1.5),
        n=st.integers(1, 8),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_reference(self, n_elems, alpha, beta, gamma, n, seed):
        key = jax.random.key(seed)
        ks = jax.random.split(key, 4)
        phi, delta, dsum, psum = (rand(k, (n_elems,)) for k in ks)
        scalars = jnp.array([alpha, beta, gamma, 1.0 / n], jnp.float32)
        got_phi, got_delta = outer_update.noloco_outer(phi, delta, dsum, psum, scalars)
        want_phi, want_delta = ref.noloco_outer(
            phi, delta, dsum, psum, alpha, beta, gamma, n
        )
        np.testing.assert_allclose(got_delta, want_delta, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(got_phi, want_phi, rtol=1e-5, atol=1e-6)

    @settings(max_examples=20, deadline=None)
    @given(
        n_elems=st.integers(1, 2 * outer_update.BLOCK),
        alpha=st.floats(0.0, 0.95),
        beta=st.floats(0.05, 1.0),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_diloco_matches_reference(self, n_elems, alpha, beta, seed):
        key = jax.random.key(seed)
        k1, k2, k3 = jax.random.split(key, 3)
        phi, delta, dmean = rand(k1, (n_elems,)), rand(k2, (n_elems,)), rand(k3, (n_elems,))
        scalars = jnp.array([alpha, beta, 0.0, 1.0], jnp.float32)
        got_phi, got_delta = outer_update.diloco_outer(phi, delta, dmean, scalars)
        want_phi, want_delta = ref.diloco_outer(phi, delta, dmean, alpha, beta)
        np.testing.assert_allclose(got_delta, want_delta, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(got_phi, want_phi, rtol=1e-5, atol=1e-6)

    def test_block_boundary_sizes(self):
        # Exactly BLOCK, BLOCK±1 — the padding path edge cases.
        for n_elems in (outer_update.BLOCK - 1, outer_update.BLOCK, outer_update.BLOCK + 1):
            key = jax.random.key(n_elems)
            ks = jax.random.split(key, 4)
            phi, delta, dsum, psum = (rand(k, (n_elems,)) for k in ks)
            scalars = jnp.array([0.5, 0.7, 0.9, 0.5], jnp.float32)
            got_phi, got_delta = outer_update.noloco_outer(phi, delta, dsum, psum, scalars)
            want_phi, want_delta = ref.noloco_outer(phi, delta, dsum, psum, 0.5, 0.7, 0.9, 2)
            np.testing.assert_allclose(got_phi, want_phi, rtol=1e-5, atol=1e-6)
            np.testing.assert_allclose(got_delta, want_delta, rtol=1e-5, atol=1e-6)

    def test_identical_group_gamma_inert(self):
        # phi == group mean -> the gamma term must vanish exactly.
        n_elems = 513
        phi = rand(jax.random.key(5), (n_elems,))
        delta = jnp.zeros_like(phi)
        dsum = jnp.zeros_like(phi)
        psum = 2.0 * phi  # n=2 group of identical replicas
        lo = outer_update.noloco_outer(
            phi, delta, dsum, psum, jnp.array([0.3, 0.7, 0.0, 0.5], jnp.float32)
        )
        hi = outer_update.noloco_outer(
            phi, delta, dsum, psum, jnp.array([0.3, 0.7, 1.2, 0.5], jnp.float32)
        )
        np.testing.assert_allclose(lo[0], hi[0], rtol=0, atol=1e-7)

    def test_lookahead_degenerate_case(self):
        # alpha=0, beta=1, gamma=0, n=1: phi' = phi + Delta = theta.
        n_elems = 100
        phi = rand(jax.random.key(6), (n_elems,))
        theta = rand(jax.random.key(7), (n_elems,))
        delta0 = jnp.zeros_like(phi)
        scalars = jnp.array([0.0, 1.0, 0.0, 1.0], jnp.float32)
        phi_new, _ = outer_update.noloco_outer(phi, delta0, theta - phi, phi, scalars)
        np.testing.assert_allclose(phi_new, theta, rtol=1e-6, atol=1e-6)
