# Build-time entry points.
#
# `make artifacts` AOT-lowers every training-time function to HLO text
# (python/compile/aot.py) under rust/artifacts/, where the Rust test
# suite and examples look for them (cargo runs with cwd = rust/). The
# Python layer never runs on the training path — this is the one
# compile step.

PYTHON ?= python3
ARTIFACTS ?= $(CURDIR)/rust/artifacts

.PHONY: artifacts test test-artifacts bench

artifacts:
	cd python && $(PYTHON) -m compile.aot --out-dir $(ARTIFACTS)

test:
	cd rust && cargo test -q

# The artifact-gated suite: every PJRT-dependent test hardens its skip
# into a failure (used by the second CI job after `make artifacts`).
test-artifacts:
	cd rust && NOLOCO_REQUIRE_ARTIFACTS=1 cargo test -q

bench:
	cd rust && cargo bench
