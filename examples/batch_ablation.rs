//! Table 3 / Appendix C driver — batch-size sensitivity.
//!
//! The paper doubles the global batch (1M → 2M tokens, medium model) and
//! finds both inner/outer methods improve, with NoLoCo benefiting
//! slightly more than DiLoCo. Here: the same sweep at CPU scale (1x and
//! 2x the preset's batch), all three methods, fixed step count — so the
//! 2x runs also see 2x the tokens, exactly as in the paper.
//!
//! ```sh
//! cargo run --release --example batch_ablation -- --preset tiny --out results/table3
//! ```

use noloco::cli::Args;
use noloco::config::{presets, Method};
use noloco::metrics::Table;
use noloco::runtime::{find_build, Engine};
use noloco::train::SimTrainer;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1)).map_err(anyhow::Error::msg)?;
    let preset = args.opt("preset").unwrap_or("tiny");
    let out = args.opt("out").unwrap_or("results/table3").to_string();
    let steps = args
        .opt_usize("steps")
        .map_err(anyhow::Error::msg)?
        .unwrap_or(160);
    std::fs::create_dir_all(&out)?;

    let base = presets::preset(preset).expect("preset");
    let dir = find_build(&base.artifacts_dir, &base.model.name, 2)?;
    let mut eng = Engine::new(dir)?;

    let batch1 = base.model.batch_tokens.max(2 * 2 * base.model.seq_len);
    let batches = [batch1, 2 * batch1];
    let methods = [Method::Fsdp, Method::DiLoCo, Method::NoLoCo];

    let mut table = Table::new(&["Method", &format!("{batch1} tok"), &format!("{} tok", 2 * batch1)]);
    let mut csv = String::from("method,batch_tokens,ppl\n");
    for method in methods {
        let mut cells = vec![method.to_string()];
        for &bt in &batches {
            let mut cfg = match method {
                Method::Fsdp => presets::as_fsdp(base.clone()),
                Method::DiLoCo => presets::as_diloco(base.clone()),
                Method::NoLoCo => base.clone(),
            };
            cfg.topology.dp = 2;
            cfg.topology.pp = 2;
            cfg.steps = steps;
            cfg.warmup = steps / 8;
            cfg.model.batch_tokens = bt;
            cfg.outer.inner_steps = match method {
                Method::DiLoCo => 20,
                _ => 10,
            };
            cfg.eval_every = 0;
            let t0 = std::time::Instant::now();
            let report = SimTrainer::new(cfg, &mut eng)?.run()?;
            println!(
                "{method} @ {bt} tokens: ppl {:.2} ({:.0}s)",
                report.final_val_ppl,
                t0.elapsed().as_secs_f64()
            );
            cells.push(format!("{:.2}", report.final_val_ppl));
            csv.push_str(&format!("{method},{bt},{:.4}\n", report.final_val_ppl));
        }
        table.row(&cells);
    }
    let md = table.to_markdown();
    println!("\n## Table 3 — batch-size ablation (CPU scale)\n\n{md}");
    println!("paper shape: larger batch improves all methods; NoLoCo ≤ DiLoCo at 2x.");
    std::fs::write(format!("{out}/table3.md"), &md)?;
    std::fs::write(format!("{out}/table3.csv"), csv)?;
    println!("written to {out}/");
    Ok(())
}
