//! End-to-end driver (DESIGN.md deliverable): train the `e2e` preset —
//! an 8-layer, 256-hidden, 4096-vocab Llama-style transformer (~12M
//! total parameters) — with NoLoCo over a DP=2 × PP=2 grid for a few
//! hundred steps on the synthetic reddit-like corpus, through the full
//! Rust → PJRT → XLA artifact stack, and log the loss curve.
//!
//! ```sh
//! make artifacts && cargo run --release --example e2e_train -- --out results/e2e
//! ```
//!
//! Options: `--steps N` (default 300), `--threaded` (run over the message
//! fabric with one engine per worker thread), `--method`, `--out DIR`.
//! The run is recorded in EXPERIMENTS.md.

use noloco::cli::{train_config_from, Args};
use noloco::runtime::{find_build, Engine};
use noloco::train::{SimTrainer, ThreadedTrainer};

fn main() -> anyhow::Result<()> {
    let mut raw: Vec<String> = std::env::args().skip(1).collect();
    // Default preset for this driver is `e2e`.
    if !raw.iter().any(|a| a.starts_with("--preset")) {
        raw.extend(["--preset".into(), "e2e".into()]);
    }
    let args = Args::parse(raw).map_err(anyhow::Error::msg)?;
    let mut cfg = train_config_from(&args).map_err(anyhow::Error::msg)?;
    if args.opt("steps").is_none() {
        cfg.steps = 300;
    }
    if args.opt("eval-every").is_none() {
        cfg.eval_every = 25;
    }
    cfg.warmup = cfg.steps / 6;
    let out = args.opt("out").unwrap_or("results/e2e").to_string();
    std::fs::create_dir_all(&out)?;

    println!(
        "e2e: {} | {} total params | {} | dp={} pp={} | {} steps | batch {} tokens",
        cfg.model.name,
        cfg.model.total_params(),
        cfg.outer.method,
        cfg.topology.dp,
        cfg.topology.pp,
        cfg.steps,
        cfg.model.batch_tokens,
    );

    if args.has_flag("threaded") {
        // Real worker threads over the message fabric — same unified
        // TrainReport as the single-process path below.
        let report = ThreadedTrainer::new(cfg.clone()).with_val_batches(8).run()?;
        println!(
            "threaded done in {:.1}s | final val ppl {:.2} | {:.1} MiB / {} msgs on the fabric",
            report.wall_secs,
            report.final_val_ppl,
            report.comm.mib_sent(),
            report.comm.msgs_sent
        );
        let mut csv = String::from("step,train_loss\n");
        for (i, l) in report.step_train_loss.iter().enumerate() {
            csv.push_str(&format!("{},{:.6}\n", i + 1, l));
        }
        std::fs::write(format!("{out}/e2e_threaded_loss.csv"), csv)?;
        println!("loss curve written to {out}/e2e_threaded_loss.csv");
        return Ok(());
    }

    let dir = find_build(&cfg.artifacts_dir, &cfg.model.name, cfg.topology.pp)?;
    let mut eng = Engine::new(dir)?;
    let mut trainer = SimTrainer::new(cfg, &mut eng)?;
    let report = trainer.run()?;

    println!("\nstep   train-loss  val-loss   val-ppl   weight-σ      lr");
    let t = &report.trace;
    for i in 0..t.steps.len() {
        println!(
            "{:>4}   {:>9.4}  {:>8.4}  {:>8.2}  {:>9.6}  {:>9.2e}",
            t.steps[i],
            t.train_loss[i],
            t.val_loss[i],
            t.val_loss[i].exp(),
            t.weight_std[i],
            t.lr[i]
        );
    }
    report.trace.write_csv(&format!("{out}/e2e_trace.csv"))?;
    println!(
        "\nfinal val ppl {:.2} | {:.1}s wall | {} XLA executions | trace -> {out}/e2e_trace.csv",
        report.final_val_ppl, report.wall_secs, report.executions
    );
    println!(
        "comm: {:.1} MiB | hops {} | blocking collectives {} | gossip pairs {}",
        report.comm.mib_sent(),
        report.comm.activation_hops,
        report.comm.blocking_collectives,
        report.comm.pair_exchanges
    );

    // Sanity: the loss must actually have gone down.
    let first = report.trace.train_loss.first().copied().unwrap_or(f64::NAN);
    let last = report.trace.train_loss.last().copied().unwrap_or(f64::NAN);
    println!("train loss {first:.3} -> {last:.3}");
    if last >= first {
        eprintln!("WARNING: loss did not improve — inspect the run");
    }
    Ok(())
}
