//! Fig. 5 driver — the paper's §5.3 latency analysis.
//!
//! * **Fig. 5A**: expected tree-all-reduce time over expected local
//!   (pair) averaging time as a function of world size `n` and message
//!   latency spread σ (log-normal). Both the analytic forms (Eq. 5: tree ≈
//!   2·t_c·log2 n; Eq. 7: E(max of 2 iid log-normals)) and the
//!   discrete-event simulation ([`SimClock`]) are reported — the sim
//!   validates the closed forms.
//! * **Fig. 5B**: ratio of *total training time* DiLoCo / NoLoCo from the
//!   global-blocking effect alone (communication itself excluded, as in
//!   the paper): DiLoCo's outer step barriers all n workers; NoLoCo's
//!   gossip only barriers pairs. Inner-step latency ~ LogNormal(μ=1,
//!   σ²=0.5), the paper's setting.
//!
//! ```sh
//! cargo run --release --example latency_analysis -- --out results/fig5
//! ```

use noloco::cli::Args;
use noloco::collective::{pair_average_time, tree_all_reduce_time};
use noloco::metrics::Table;
use noloco::net::{erf, LatencyModel, SimClock};
use noloco::rngx::Pcg64;

/// Analytic Eq. 7: E(max(t1,t2)) for iid LogNormal(mu, sigma^2).
fn expected_max2(mu: f64, sigma: f64) -> f64 {
    (1.0 + erf(sigma / 2.0)) * (mu + sigma * sigma / 2.0).exp()
}

fn fig5a(out: &str) -> anyhow::Result<()> {
    let mut table = Table::new(&[
        "n", "σ", "tree (sim)", "pair (sim)", "ratio (sim)", "ratio (analytic)",
    ]);
    let mut csv = String::from("n,sigma,ratio_sim,ratio_analytic\n");
    let trials = 200;
    for &sigma in &[0.125f64, 0.5, 1.0] {
        for &n in &[4usize, 8, 16, 32, 64, 128, 256, 512, 1024] {
            let model = LatencyModel::LogNormal { mu: 0.0, sigma };
            let (mut tree, mut pair) = (0.0, 0.0);
            let reps = if n > 256 { trials / 4 } else { trials };
            for seed in 0..reps {
                let mut clock = SimClock::new(n, model.clone(), seed as u64);
                tree += tree_all_reduce_time(&mut clock);
                let mut clock = SimClock::new(n, model.clone(), 10_000 + seed as u64);
                pair += pair_average_time(&mut clock, None);
            }
            let (tree, pair) = (tree / reps as f64, pair / reps as f64);
            let ratio_sim = tree / pair;
            // Analytic: tree ≈ 2·log2(n) generations each costing
            // E(max over contending children) ~ Eq. 7's pairwise max;
            // local averaging = 2·E(t_local) (§5.3).
            let t_c = (0.0f64 + sigma * sigma / 2.0).exp();
            let tree_analytic = 2.0 * (n as f64).log2() * expected_max2(0.0, sigma) / 2.0
                + t_c * (n as f64).log2();
            let pair_analytic = 2.0 * expected_max2(0.0, sigma) / 2.0 + t_c;
            let ratio_analytic = tree_analytic / pair_analytic;
            table.row(&[
                n.to_string(),
                format!("{sigma}"),
                format!("{tree:.2}"),
                format!("{pair:.2}"),
                format!("{ratio_sim:.2}"),
                format!("{ratio_analytic:.2}"),
            ]);
            csv.push_str(&format!("{n},{sigma},{ratio_sim:.3},{ratio_analytic:.3}\n"));
        }
    }
    let md = table.to_markdown();
    println!("## Fig. 5A — tree-reduce vs local-averaging expected time\n\n{md}");
    std::fs::write(format!("{out}/fig5a.md"), &md)?;
    std::fs::write(format!("{out}/fig5a.csv"), csv)?;
    Ok(())
}

/// Fig. 5B: makespan of `outer_rounds` outer steps where each inner phase
/// costs the sum of `m` LogNormal(mu, sigma) draws, under the two blocking
/// disciplines. Communication time itself excluded.
fn blocking_ratio(
    n: usize,
    m: usize,
    outer_rounds: usize,
    mu: f64,
    sigma: f64,
    seed: u64,
) -> f64 {
    let mut rng = Pcg64::seed_from_u64(seed);
    // DiLoCo: a global barrier per outer round — the makespan is
    // sum over rounds of max_i(inner phase time).
    let mut diloco = 0.0f64;
    // NoLoCo: pairwise barriers — per-worker clocks, paired each round.
    let mut clocks = vec![0.0f64; n];
    for _round in 0..outer_rounds {
        let mut round_max = 0.0f64;
        let phases: Vec<f64> = (0..n)
            .map(|_| (0..m).map(|_| rng.log_normal(mu, sigma)).sum::<f64>())
            .collect();
        for &p in &phases {
            round_max = round_max.max(p);
        }
        diloco += round_max;
        let pairs = rng.random_pairs(n);
        for (a, b) in pairs {
            match b {
                Some(b) => {
                    let t = (clocks[a] + phases[a]).max(clocks[b] + phases[b]);
                    clocks[a] = t;
                    clocks[b] = t;
                }
                None => clocks[a] += phases[a],
            }
        }
    }
    let noloco = clocks.iter().fold(0.0f64, |acc, &t| acc.max(t));
    diloco / noloco
}

fn fig5b(out: &str) -> anyhow::Result<()> {
    // Paper setting: inner-step latency LogNormal(mu=1, sigma^2=0.5);
    // NoLoCo at 2x outer frequency (50 vs 100 inner steps) — we sweep m.
    let (mu, sigma2) = (1.0f64, 0.5f64);
    let sigma = sigma2.sqrt();
    let rounds = 250;
    let mut table = Table::new(&["n", "m=25", "m=50", "m=100"]);
    let mut csv = String::from("n,m,ratio\n");
    for &n in &[16usize, 64, 256, 1024] {
        let mut cells = vec![n.to_string()];
        for &m in &[25usize, 50, 100] {
            // Average a few seeds for stability.
            let reps = 5;
            let r: f64 = (0..reps)
                .map(|s| blocking_ratio(n, m, rounds, mu, sigma, 100 + s))
                .sum::<f64>()
                / reps as f64;
            cells.push(format!("{r:.3}"));
            csv.push_str(&format!("{n},{m},{r:.4}\n"));
        }
        table.row(&cells);
    }
    let md = table.to_markdown();
    println!("\n## Fig. 5B — total-time ratio DiLoCo / NoLoCo (blocking only)\n\n{md}");
    println!(
        "paper: ratio grows with world size; ~1.2 at n=1024, m=100. \
         More frequent outer steps (smaller m) increase the overhead."
    );
    std::fs::write(format!("{out}/fig5b.md"), &md)?;
    std::fs::write(format!("{out}/fig5b.csv"), csv)?;
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1)).map_err(anyhow::Error::msg)?;
    let out = args.opt("out").unwrap_or("results/fig5").to_string();
    std::fs::create_dir_all(&out)?;

    // Eq. 7 self-check: closed form vs Monte Carlo.
    let (mu, sigma) = (0.0, 0.7);
    let mut rng = Pcg64::seed_from_u64(1);
    let mc: f64 = (0..200_000)
        .map(|_| rng.log_normal(mu, sigma).max(rng.log_normal(mu, sigma)))
        .sum::<f64>()
        / 200_000.0;
    let analytic = expected_max2(mu, sigma);
    println!(
        "Eq. 7 check: E(max of two LogNormal({mu},{sigma}²)) analytic {analytic:.4} vs MC {mc:.4}\n"
    );
    assert!((analytic - mc).abs() / analytic < 0.02);

    fig5a(&out)?;
    fig5b(&out)?;
    println!("\nwritten to {out}/fig5a.* and {out}/fig5b.*");
    Ok(())
}
