//! Quickstart: train a tiny Llama-style model with NoLoCo in ~a minute.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! Demonstrates the public API end to end: resolve an artifact build, spin
//! up the PJRT engine, run the single-process trainer, inspect the report.

use noloco::config::presets;
use noloco::runtime::{find_build, Engine};
use noloco::train::SimTrainer;

fn main() -> anyhow::Result<()> {
    // 1. Config: the `tiny` preset (64-hidden, 4-layer, 512-vocab model),
    //    NoLoCo method with the paper's α=0.5, β=0.7 and a γ from the
    //    Eq. 74 stability window. dp=2 replicas × pp=2 stages.
    let mut cfg = presets::preset("tiny").expect("builtin preset");
    cfg.steps = 60;
    cfg.warmup = 10;
    cfg.eval_every = 20;
    println!(
        "model: {} ({} transformer params) | method: {} | dp={} pp={}",
        cfg.model.name,
        cfg.model.transformer_params(),
        cfg.outer.method,
        cfg.topology.dp,
        cfg.topology.pp
    );

    // 2. Artifacts: compiled by `make artifacts` (Python never runs here).
    let dir = find_build(&cfg.artifacts_dir, &cfg.model.name, cfg.topology.pp)?;
    println!("artifacts: {}", dir.display());
    let mut eng = Engine::new(dir)?;

    // 3. Train.
    let mut trainer = SimTrainer::new(cfg, &mut eng)?;
    let report = trainer.run()?;

    // 4. Inspect.
    println!("\nstep   train-loss  val-loss   val-ppl   weight-σ");
    let t = &report.trace;
    for i in 0..t.steps.len() {
        println!(
            "{:>4}   {:>9.4}  {:>8.4}  {:>8.2}  {:>9.6}",
            t.steps[i],
            t.train_loss[i],
            t.val_loss[i],
            t.val_loss[i].exp(),
            t.weight_std[i]
        );
    }
    println!(
        "\nfinal val ppl {:.2} | {:.1}s wall | {} XLA executions",
        report.final_val_ppl, report.wall_secs, report.executions
    );
    println!(
        "communication: {:.1} MiB | blocking collectives: {} (NoLoCo: always 0) | gossip pairs: {}",
        report.comm.mib_sent(),
        report.comm.blocking_collectives,
        report.comm.pair_exchanges
    );
    Ok(())
}
