//! Straggler sweep for the bounded-staleness async boundary engine.
//!
//! Two questions, two harnesses:
//!
//! * **Systems** (cost model, [`boundary_idle_times`]): on a 3-region
//!   WAN with one progressively slower straggler, how much boundary
//!   idle time does the lockstep (gated) barrier accumulate vs the
//!   async wait-only-for-your-pair discipline? The straggler multiplier
//!   sweeps 1× → 16×, scaling both its link and its inner-phase compute.
//! * **Optimization** (quadratic Theorem-1 harness): does NoLoCo's
//!   consensus survive folding *stale* partner state? One replica's
//!   contributions arrive `lag` boundaries late (its partners fold its
//!   old (Δ, φ) — the admission the async engine performs for
//!   `lag < staleness`); the run must stay in the converged regime for
//!   every swept lag.
//!
//! ```sh
//! cargo run --release --example async_gossip -- --out results/async_gossip
//! ```

use noloco::bench::lockstep_vs_async_idle;
use noloco::cli::Args;
use noloco::config::{NetPreset, NetTopoConfig, OuterConfig};
use noloco::metrics::Table;
use noloco::optim::{NolocoOuter, OuterState, Sgd};
use noloco::quad::Quadratic;
use noloco::rngx::Pcg64;
use noloco::tensor::Tensor;

const WORLD: usize = 24;
const ROUNDS: u64 = 200;
/// The straggling node (last of the world).
const STRAGGLER: usize = WORLD - 1;

/// One sweep point: mean per-worker idle per boundary under both
/// disciplines at `world` workers, with the last node slowed `mult`× in
/// link and compute — the shared `bench::lockstep_vs_async_idle` walk,
/// so the example and `bench_topo`'s boundary-idle section cannot drift.
fn idle_at(world: usize, rounds: u64, mult: f64, payload: u64, seed: u64) -> (f64, f64) {
    let cfg = NetTopoConfig {
        preset: NetPreset::MultiRegionWan,
        regions: 3,
        ..NetTopoConfig::default()
    };
    lockstep_vs_async_idle(&cfg, world, payload, rounds, Some((world - 1, mult)), seed)
}

/// Quadratic consensus with one lagging replica: replica [`STRAGGLER`]'s
/// partners fold its (Δ, φ) from `lag` boundaries back (uniform weight —
/// harsher than the engine's 1/(1+age) decay). Returns (final mean loss,
/// final replica variance).
fn quad_stale(problem: &Quadratic, lag: usize, outer_steps: usize, seed: u64) -> (f64, f64) {
    let n = 8usize;
    let straggler = n - 1;
    let m = 10;
    let outer = OuterConfig {
        method: noloco::config::Method::NoLoCo,
        alpha: 0.5,
        beta: 0.7,
        gamma: OuterConfig::default_gamma(0.5, 2),
        group: 2,
        inner_steps: m,
        staleness: lag + 1,
    };
    let opt = NolocoOuter { alpha: outer.alpha, beta: outer.beta, gamma: outer.gamma };
    let sgd = Sgd::new(0.1);
    let d = problem.dim;

    let mut rng = Pcg64::seed_from_u64(seed);
    let init: Vec<f32> = (0..d).map(|_| rng.normal(0.0, 2.0) as f32).collect();
    let init_t = Tensor::from_vec(init, &[d]);
    let mut states: Vec<OuterState> = (0..n)
        .map(|_| OuterState::new(std::slice::from_ref(&init_t)))
        .collect();
    let mut worker_rngs: Vec<Pcg64> = (0..n).map(|_| rng.split()).collect();
    // History of the straggler's offered (Δ, φ), newest last.
    let mut history: Vec<(Vec<Tensor>, Vec<Tensor>)> = Vec::new();

    for t in 0..outer_steps {
        // Inner phase.
        let mut thetas: Vec<Vec<Tensor>> = Vec::with_capacity(n);
        for i in 0..n {
            let mut theta = states[i].phi.clone();
            for _ in 0..m {
                let th64: Vec<f64> = theta[0].as_slice().iter().map(|&x| x as f64).collect();
                let g = problem.grad(&th64, &mut worker_rngs[i]);
                let gt = Tensor::from_vec(g.iter().map(|&x| x as f32).collect(), &[d]);
                sgd.step(&mut theta, std::slice::from_ref(&gt));
            }
            thetas.push(theta);
        }
        let deltas: Vec<Vec<Tensor>> = (0..n).map(|i| states[i].outer_grad(&thetas[i])).collect();
        let phis: Vec<Vec<Tensor>> = states.iter().map(|s| s.phi.clone()).collect();
        history.push((deltas[straggler].clone(), phis[straggler].clone()));

        // Gossip pairs; the straggler's partner sees its state `lag`
        // boundaries back (clipped to what exists).
        let mut prng = Pcg64::seed_from_u64(seed ^ 0x9055 ^ t as u64);
        for (a, b) in prng.random_pairs(n) {
            let Some(b) = b else {
                states[a].step_group_with(
                    &opt,
                    &thetas[a],
                    std::slice::from_ref(&deltas[a]),
                    std::slice::from_ref(&phis[a]),
                );
                continue;
            };
            let stale_of = |i: usize| -> (Vec<Tensor>, Vec<Tensor>) {
                if i == straggler {
                    let back = history.len().saturating_sub(1 + lag);
                    history[back].clone()
                } else {
                    (deltas[i].clone(), phis[i].clone())
                }
            };
            let (da, pa) = stale_of(a);
            let (db, pb) = stale_of(b);
            // Each side folds what it *received*: the straggler's own
            // update uses its current state plus the partner's fresh one.
            states[a].step_group_with(
                &opt,
                &thetas[a],
                &[deltas[a].clone(), db.clone()],
                &[phis[a].clone(), pb.clone()],
            );
            states[b].step_group_with(
                &opt,
                &thetas[b],
                &[deltas[b].clone(), da],
                &[phis[b].clone(), pa],
            );
        }
    }

    let mean_loss = (0..n)
        .map(|i| {
            let th: Vec<f64> = states[i].phi[0].as_slice().iter().map(|&x| x as f64).collect();
            problem.loss(&th)
        })
        .sum::<f64>()
        / n as f64;
    let mut mean = vec![0.0f64; d];
    for s in &states {
        for (m, x) in mean.iter_mut().zip(s.phi[0].as_slice()) {
            *m += *x as f64 / n as f64;
        }
    }
    let mut var = 0.0;
    for j in 0..d {
        let v: f64 = states
            .iter()
            .map(|s| {
                let x = s.phi[0].as_slice()[j] as f64 - mean[j];
                x * x
            })
            .sum::<f64>()
            / n as f64;
        var += v / d as f64;
    }
    (mean_loss, var)
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1)).map_err(anyhow::Error::msg)?;
    let out = args.opt("out").unwrap_or("results/async_gossip").to_string();
    std::fs::create_dir_all(&out)?;

    let payload = 2u64 * (4 << 20); // both directions of (Δ, φ)
    println!(
        "## Straggler sweep — {WORLD} workers, 3-region WAN, {:.0} MiB (Δ, φ), {ROUNDS} rounds\n",
        payload as f64 / (1024.0 * 1024.0)
    );

    // ---- lockstep vs async boundary idle across straggler severity ----
    let mut table = Table::new(&[
        "straggler x", "lockstep idle (s)", "async idle (s)", "stall reduction",
    ]);
    let mut csv = String::from("mult,lockstep_idle,async_idle,reduction\n");
    let mut gaps = Vec::new();
    for mult in [1.0, 2.0, 4.0, 8.0, 16.0] {
        let (lock, asy) = idle_at(WORLD, ROUNDS, mult, payload, 11);
        assert!(
            asy <= lock + 1e-12,
            "async idle must never exceed lockstep: {asy} vs {lock} at {mult}x"
        );
        let red = 1.0 - asy / lock;
        table.row(&[
            format!("{mult:.0}"),
            format!("{lock:.3}"),
            format!("{asy:.3}"),
            format!("{red:.3}"),
        ]);
        csv.push_str(&format!("{mult},{lock:.5},{asy:.5},{red:.4}\n"));
        gaps.push(lock - asy);
    }
    let md = table.to_markdown();
    println!("## Lockstep vs async boundary idle\n\n{md}");
    std::fs::write(format!("{out}/idle.md"), &md)?;
    std::fs::write(format!("{out}/idle.csv"), csv)?;
    assert!(
        gaps.last().unwrap() > gaps.first().unwrap(),
        "the async gap must widen as the straggler slows: {gaps:?}"
    );
    println!(
        "\nThe slower the straggler, the more the lockstep barrier charges everyone for it; \
         the async boundary bills only its pair (gap grows {:.2}s -> {:.2}s).\n",
        gaps.first().unwrap(),
        gaps.last().unwrap()
    );

    // ---- world-size scaling: one straggler at 24 vs 1000 replicas ----
    //
    // The lockstep barrier's bill for one 8× straggler is charged to
    // *every* worker, so the per-worker idle barely moves with world
    // size; the async discipline bills only the straggler's pair, so
    // its per-worker idle *shrinks* as the fleet grows — the O(1000)
    // regime is where wait-only-for-your-pair pays most.
    let mut table = Table::new(&["world", "lockstep idle (s)", "async idle (s)", "reduction"]);
    let mut by_world = Vec::new();
    for world in [24usize, 256, 1000] {
        let (lock, asy) = idle_at(world, 50, 8.0, payload, 11);
        assert!(asy <= lock + 1e-12, "async idle exceeded lockstep at world {world}");
        table.row(&[
            world.to_string(),
            format!("{lock:.3}"),
            format!("{asy:.3}"),
            format!("{:.3}", 1.0 - asy / lock),
        ]);
        by_world.push((lock, asy));
    }
    let md = table.to_markdown();
    println!("## One 8x straggler across world sizes\n\n{md}");
    std::fs::write(format!("{out}/scale.md"), &md)?;
    let (_, asy_small) = by_world[0];
    let (_, asy_large) = by_world[by_world.len() - 1];
    assert!(
        asy_large < asy_small,
        "per-worker async idle should shrink with world size: {asy_large} vs {asy_small}"
    );

    // ---- bounded-staleness convergence on the quadratic harness ----
    let mut prng = Pcg64::seed_from_u64(5);
    let problem = Quadratic::new(8, 0.2, 1.0, 0.5, &mut prng);
    let mut table = Table::new(&["partner lag (boundaries)", "final mean loss", "replica var"]);
    let mut losses = Vec::new();
    for lag in [0usize, 1, 3] {
        let (loss, var) = quad_stale(&problem, lag, 120, 21);
        table.row(&[
            lag.to_string(),
            format!("{loss:.3e}"),
            format!("{var:.3e}"),
        ]);
        losses.push(loss);
    }
    let md = table.to_markdown();
    println!("## NoLoCo consensus under stale partner state (quadratic, Theorem 1 setting)\n\n{md}");
    std::fs::write(format!("{out}/staleness.md"), &md)?;
    let fresh = losses[0];
    for (i, &l) in losses.iter().enumerate() {
        assert!(
            l < fresh * 20.0 + 1e-3,
            "lagged run {i} left the converged regime: {l:.3e} vs fresh {fresh:.3e}"
        );
    }
    println!(
        "\nFolding a partner's state a few boundaries late leaves the consensus intact — \
         the bounded-staleness window trades a bounded bias for never stalling on the \
         straggler.\n\nwritten to {out}/idle.* and {out}/staleness.md"
    );
    Ok(())
}
