//! WAN + churn scenario driver — the elastic-membership counterpart of
//! the paper's §5.3 latency analysis.
//!
//! Twelve workers spread over a **three-region WAN** (fast intra-region
//! links, slow high-variance inter-region links, one straggler node) run
//! the same training schedule under all three methods while the
//! membership churns: one node **leaves** mid-run, later **rejoins**, and
//! another leaves for good. Two comparisons come out:
//!
//! * **Completion time** (virtual clock, [`SimClock::with_topology`]):
//!   NoLoCo's gossip pairs re-draw over the survivors, so churn costs it
//!   nothing global; FSDP / DiLoCo must stall the whole world on every
//!   membership event (detect the dead member, rebuild the group,
//!   re-broadcast state) and their payload-aware tree all-reduce drags
//!   across the slow inter-region links every sync.
//! * **Convergence** (quadratic Theorem-1 harness): NoLoCo's consensus
//!   absorbs a leave + rejoin with a final loss close to the churn-free
//!   run, while a global-barrier method simply cannot finish the run.
//!
//! ```sh
//! cargo run --release --example wan_churn -- --out results/wan_churn
//! ```

use noloco::cli::Args;
use noloco::collective::tree_all_reduce_time_over;
use noloco::config::{presets, OuterConfig};
use noloco::metrics::Table;
use noloco::net::topo::{ChurnEvent, ChurnSchedule, Link, Membership, Topology};
use noloco::net::{LatencyModel, SimClock};
use noloco::optim::{NolocoOuter, Sgd};
use noloco::quad::Quadratic;
use noloco::rngx::Pcg64;
use noloco::tensor::Tensor;

const WORLD: usize = 12;
const STEPS: usize = 240;
/// Inner compute time per step: LogNormal(-1, 0.45²) seconds (~0.37 s).
const COMPUTE_MU: f64 = -1.0;
const COMPUTE_SIGMA: f64 = 0.45;
/// Stall a global collective pays when a membership event interrupts it:
/// peer-death detection timeout before the group can be rebuilt.
const DETECT_TIMEOUT_SECS: f64 = 30.0;

/// The scenario's network: 3 regions of 4; 1 ms / 1 GB/s inside a region,
/// 80 ms median / 12.5 MB/s across regions (log-normal, σ = 0.6), and
/// node 11 on a 3× oversubscribed uplink.
fn wan() -> Topology {
    Topology::multi_region(
        &[4, 4, 4],
        Link::new(LatencyModel::Constant(1e-3), 1e9),
        Link::new(LatencyModel::LogNormal { mu: (80e-3f64).ln(), sigma: 0.6 }, 1.25e7),
    )
    .with_straggler(11, 3.0)
}

/// The scenario's churn: node 5 leaves at step 40 and rejoins at step
/// 120; node 9 leaves at step 160 for good.
fn churn() -> ChurnSchedule {
    ChurnSchedule::none().leave(40, 5).join(120, 5).leave(160, 9)
}

struct Outcome {
    name: &'static str,
    makespan: f64,
    syncs: usize,
    sync_secs: f64,
    stall_secs: f64,
    completed: bool,
}

/// Walk the training schedule on the virtual clock. `sync_every` = inner
/// steps per synchronization (1 for FSDP); `global` selects tree
/// all-reduce over the live set (+ stall on churn) vs gossip pairs.
fn simulate(
    name: &'static str,
    global: bool,
    sync_every: usize,
    payload: u64,
    seed: u64,
) -> Outcome {
    let mut clock = SimClock::with_topology(wan(), seed);
    let mut member = Membership::full(WORLD);
    let mut rng = Pcg64::seed_from_u64(seed ^ 0xc1c1);
    let schedule = churn();
    let (mut syncs, mut sync_secs, mut stall_secs) = (0usize, 0.0f64, 0.0f64);

    for step in 0..STEPS {
        // ---- membership events fire at the start of the step ----
        for event in schedule.events_at(step as u64) {
            let was_live = member.live_nodes();
            member.apply(event);
            if global {
                // A collective group has no live-subset form: every
                // member stalls until the change is detected, then the
                // group is rebuilt and the root re-broadcasts state.
                let t = was_live
                    .iter()
                    .map(|&w| clock.ready_at(w))
                    .fold(0.0, f64::max)
                    + DETECT_TIMEOUT_SECS;
                for &w in &member.live_nodes() {
                    let r = clock.ready_at(w);
                    clock.compute(w, t - r);
                }
                stall_secs += DETECT_TIMEOUT_SECS;
                let live = member.live_nodes();
                let before = clock.makespan();
                tree_all_reduce_time_over(&mut clock, &live, payload);
                stall_secs += clock.makespan() - before;
            } else if let ChurnEvent::Join(node) = event {
                // Gossip join: the node resumes at the current frontier
                // and catches up through its next pair exchange — nobody
                // else waits.
                let t = member
                    .live_nodes()
                    .iter()
                    .map(|&w| clock.ready_at(w))
                    .fold(0.0, f64::max);
                let r = clock.ready_at(node);
                clock.compute(node, t - r);
            }
        }

        // ---- inner compute: every live worker advances independently ----
        for &w in &member.live_nodes() {
            let dt = clock.draw_log_normal(COMPUTE_MU, COMPUTE_SIGMA);
            clock.compute(w, dt);
        }

        // ---- synchronization ----
        if (step + 1) % sync_every == 0 {
            let live = member.live_nodes();
            let before = clock.makespan();
            if global {
                tree_all_reduce_time_over(&mut clock, &live, payload);
            } else {
                // Fresh random disjoint pairs over the live set; each
                // pair exchanges (Δ, φ) — twice the payload, but only
                // between the two members.
                let pairs = rng.random_pairs(live.len());
                for (a, b) in pairs {
                    if let Some(b) = b {
                        clock.exchange_bytes(live[a], live[b], 2 * payload);
                    }
                }
            }
            syncs += 1;
            sync_secs += clock.makespan() - before;
        }
    }

    let makespan = member
        .live_nodes()
        .iter()
        .map(|&w| clock.ready_at(w))
        .fold(0.0, f64::max);
    Outcome { name, makespan, syncs, sync_secs, stall_secs, completed: true }
}

/// Synthetic churn schedule at `rate` leave events per 100 steps: nodes
/// (cycling 1.., node 0 never leaves) drop out at evenly spaced steps
/// and rejoin 30 steps later when the run allows.
fn schedule_at_rate(rate: usize) -> ChurnSchedule {
    let mut s = ChurnSchedule::none();
    let n_leaves = rate * STEPS / 100;
    if n_leaves == 0 {
        return s;
    }
    let spacing = (STEPS - 60) / n_leaves;
    for i in 0..n_leaves {
        let node = 1 + (i % (WORLD - 1));
        let at = (30 + i * spacing) as u64;
        s = s.leave(at, node);
        if at + 30 < STEPS as u64 {
            s = s.join(at + 30, node);
        }
    }
    s
}

/// How many sync rounds survivors keep gossiping with an unannounced
/// dead peer before the heartbeat detector declares it (the `[churn]
/// misses` knob's cost-model counterpart).
const DETECT_MISSES: usize = 2;
/// What a survivor pays when its drawn partner is dead but not yet
/// detected: the gossip straggler timeout.
const GOSSIP_TIMEOUT_SECS: f64 = 5.0;

struct GossipOutcome {
    makespan: f64,
    detect_stall: f64,
    wasted_rounds: usize,
}

/// NoLoCo-only walk under `schedule`, *scheduled* (membership changes
/// are announced: pairs never include a dead node) vs *detected* (a
/// leave is unannounced: survivors keep drawing the dead node for
/// [`DETECT_MISSES`] sync rounds and pay [`GOSSIP_TIMEOUT_SECS`] when
/// paired with it — the failure detector's price; a rejoin is noticed at
/// its next heartbeat, i.e. the next sync round, like the scheduled
/// walk).
fn simulate_gossip(
    schedule: &ChurnSchedule,
    detected: bool,
    payload: u64,
    seed: u64,
) -> GossipOutcome {
    let sync_every = 10usize;
    let mut clock = SimClock::with_topology(wan(), seed);
    let mut member = Membership::full(WORLD);
    let mut rng = Pcg64::seed_from_u64(seed ^ 0xde7ec7);
    // Dead-but-undetected nodes: (node, sync rounds until detection).
    let mut undetected: Vec<(usize, usize)> = Vec::new();
    let (mut detect_stall, mut wasted_rounds) = (0.0f64, 0usize);

    for step in 0..STEPS {
        for event in schedule.events_at(step as u64) {
            let node = event.node();
            match event {
                ChurnEvent::Leave(_) => {
                    member.apply(event);
                    if detected {
                        undetected.push((node, DETECT_MISSES));
                    }
                }
                ChurnEvent::Join(_) => {
                    member.apply(event);
                    undetected.retain(|&(n, _)| n != node);
                    // Rejoiner resumes at the frontier; nobody waits.
                    let t = member
                        .live_nodes()
                        .iter()
                        .map(|&w| clock.ready_at(w))
                        .fold(0.0, f64::max);
                    let r = clock.ready_at(node);
                    clock.compute(node, t - r);
                }
            }
        }

        for &w in &member.live_nodes() {
            let dt = clock.draw_log_normal(COMPUTE_MU, COMPUTE_SIGMA);
            clock.compute(w, dt);
        }

        if (step + 1) % sync_every == 0 {
            // Pairs are drawn over what the survivors *believe* is live:
            // the actual live set plus any dead-but-undetected nodes.
            let mut believed = member.live_nodes();
            for &(n, _) in &undetected {
                believed.push(n);
            }
            believed.sort_unstable();
            let pairs = rng.random_pairs(believed.len());
            for (a, b) in pairs {
                let (ra, rb) = (believed[a], b.map(|j| believed[j]));
                let Some(rb) = rb else { continue };
                let a_dead = !member.is_live(ra);
                let b_dead = !member.is_live(rb);
                match (a_dead, b_dead) {
                    (false, false) => {
                        clock.exchange_bytes(ra, rb, 2 * payload);
                    }
                    (false, true) => {
                        clock.compute(ra, GOSSIP_TIMEOUT_SECS);
                        detect_stall += GOSSIP_TIMEOUT_SECS;
                        wasted_rounds += 1;
                    }
                    (true, false) => {
                        clock.compute(rb, GOSSIP_TIMEOUT_SECS);
                        detect_stall += GOSSIP_TIMEOUT_SECS;
                        wasted_rounds += 1;
                    }
                    (true, true) => {}
                }
            }
            // One sync round of silence burned per undetected node.
            for e in undetected.iter_mut() {
                e.1 -= 1;
            }
            undetected.retain(|&(_, left)| left > 0);
        }
    }

    let makespan = member
        .live_nodes()
        .iter()
        .map(|&w| clock.ready_at(w))
        .fold(0.0, f64::max);
    GossipOutcome { makespan, detect_stall, wasted_rounds }
}

/// Quadratic consensus under churn: replicas run inner SGD + gossip
/// outer steps while the live set follows `schedule` (a rejoiner absorbs
/// a live donor's state). Returns (final mean loss, final replica var).
fn quad_churn(
    problem: &Quadratic,
    outer_steps: usize,
    schedule: &ChurnSchedule,
    seed: u64,
) -> (f64, f64) {
    let n = 8;
    let m = 10;
    let omega = 0.1;
    let outer = OuterConfig {
        method: noloco::config::Method::NoLoCo,
        alpha: 0.5,
        beta: 0.7,
        gamma: OuterConfig::default_gamma(0.5, 2),
        group: 2,
        inner_steps: m,
        staleness: 1,
    };
    let opt = NolocoOuter { alpha: outer.alpha, beta: outer.beta, gamma: outer.gamma };
    let sgd = Sgd::new(omega);
    let d = problem.dim;

    let mut rng = Pcg64::seed_from_u64(seed);
    let init: Vec<f32> = (0..d).map(|_| (rng.normal(0.0, 2.0)) as f32).collect();
    let init_t = Tensor::from_vec(init, &[d]);
    let mut states: Vec<noloco::optim::OuterState> = (0..n)
        .map(|_| noloco::optim::OuterState::new(std::slice::from_ref(&init_t)))
        .collect();
    let mut worker_rngs: Vec<Pcg64> = (0..n).map(|_| rng.split()).collect();
    let mut member = Membership::full(n);

    for t in 0..outer_steps {
        for event in schedule.events_at(t as u64) {
            if let ChurnEvent::Join(node) = event {
                if !member.is_live(node) {
                    // Absorb the lowest live donor's consensus state.
                    if let Some(&donor) = member.live_nodes().first() {
                        states[node] = states[donor].clone();
                    }
                }
            }
            member.apply(event);
        }
        let live = member.live_nodes();
        // Inner phase on the live replicas.
        let mut thetas: Vec<Vec<Tensor>> = vec![Vec::new(); n];
        for &i in &live {
            let mut theta = states[i].phi.clone();
            for _ in 0..m {
                let th64: Vec<f64> =
                    theta[0].as_slice().iter().map(|&x| x as f64).collect();
                let g = problem.grad(&th64, &mut worker_rngs[i]);
                let gt = Tensor::from_vec(g.iter().map(|&x| x as f32).collect(), &[d]);
                sgd.step(&mut theta, std::slice::from_ref(&gt));
            }
            thetas[i] = theta;
        }
        // Gossip pairs over the live set.
        let deltas: Vec<Vec<Tensor>> = (0..n)
            .map(|i| {
                if member.is_live(i) {
                    states[i].outer_grad(&thetas[i])
                } else {
                    Vec::new()
                }
            })
            .collect();
        let phis: Vec<Vec<Tensor>> = states.iter().map(|s| s.phi.clone()).collect();
        for (a, b) in rng.random_pairs(live.len()) {
            let (ra, rb) = (live[a], b.map(|b| live[b]));
            match rb {
                Some(rb) => {
                    let gd = [deltas[ra].clone(), deltas[rb].clone()];
                    let gp = [phis[ra].clone(), phis[rb].clone()];
                    states[ra].step_group_with(&opt, &thetas[ra], &gd, &gp);
                    states[rb].step_group_with(&opt, &thetas[rb], &gd, &gp);
                }
                None => {
                    let gd = [deltas[ra].clone()];
                    let gp = [phis[ra].clone()];
                    states[ra].step_group_with(&opt, &thetas[ra], &gd, &gp);
                }
            }
        }
    }

    let live = member.live_nodes();
    let mean_loss = live
        .iter()
        .map(|&i| {
            let th: Vec<f64> = states[i].phi[0].as_slice().iter().map(|&x| x as f64).collect();
            problem.loss(&th)
        })
        .sum::<f64>()
        / live.len() as f64;
    // Replica spread over live members.
    let mut mean = vec![0.0f64; d];
    for &i in &live {
        for (m, x) in mean.iter_mut().zip(states[i].phi[0].as_slice()) {
            *m += *x as f64 / live.len() as f64;
        }
    }
    let mut var = 0.0;
    for j in 0..d {
        let v: f64 = live
            .iter()
            .map(|&i| {
                let x = states[i].phi[0].as_slice()[j] as f64 - mean[j];
                x * x
            })
            .sum::<f64>()
            / live.len() as f64;
        var += v / d as f64;
    }
    (mean_loss, var)
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1)).map_err(anyhow::Error::msg)?;
    let out = args.opt("out").unwrap_or("results/wan_churn").to_string();
    std::fs::create_dir_all(&out)?;

    let model = presets::preset("small").unwrap().model;
    let payload = (model.total_params() * 4) as u64;
    let topo = wan();
    println!(
        "## Scenario — {WORLD} workers, {} regions, payload {:.1} MiB, churn {:?}\n",
        topo.regions(),
        payload as f64 / (1024.0 * 1024.0),
        churn().events(),
    );

    // ---- completion-time comparison on the virtual clock ----
    let runs = [
        simulate("FSDP", true, 1, payload, 7),
        simulate("DiLoCo", true, 20, payload, 7),
        simulate("NoLoCo", false, 10, payload, 7),
    ];
    let mut table = Table::new(&[
        "method", "makespan (s)", "syncs", "sync cost (s)", "churn stalls (s)", "status",
    ]);
    let mut csv = String::from("method,makespan,syncs,sync_secs,stall_secs\n");
    for r in &runs {
        table.row(&[
            r.name.to_string(),
            format!("{:.1}", r.makespan),
            r.syncs.to_string(),
            format!("{:.1}", r.sync_secs),
            format!("{:.1}", r.stall_secs),
            if r.completed { "completed".into() } else { "aborted".into() },
        ]);
        csv.push_str(&format!(
            "{},{:.2},{},{:.2},{:.2}\n",
            r.name, r.makespan, r.syncs, r.sync_secs, r.stall_secs
        ));
    }
    let md = table.to_markdown();
    println!("## Completion time over the 3-region WAN with churn\n\n{md}");
    std::fs::write(format!("{out}/completion.md"), &md)?;
    std::fs::write(format!("{out}/completion.csv"), csv)?;

    let noloco = &runs[2];
    let diloco = &runs[1];
    assert_eq!(noloco.stall_secs, 0.0, "NoLoCo must not stall globally on churn");
    assert!(
        diloco.stall_secs > 0.0 && diloco.makespan > noloco.makespan,
        "DiLoCo's all-reduce must visibly degrade under churn: \
         diloco {:.1}s vs noloco {:.1}s",
        diloco.makespan,
        noloco.makespan,
    );
    println!(
        "\nNoLoCo finished in {:.0} s with zero global stalls; DiLoCo paid {:.0} s of \
         churn stalls on top of {:.0} s of cross-region all-reduces ({:.1}x slower \
         overall); FSDP, syncing every step, took {:.1}x NoLoCo's time.\n",
        noloco.makespan,
        diloco.stall_secs,
        diloco.sync_secs,
        diloco.makespan / noloco.makespan,
        runs[0].makespan / noloco.makespan,
    );

    // ---- churn-rate sweep: scheduled vs detected membership ----
    let mut table = Table::new(&[
        "leaves / 100 steps",
        "scheduled makespan (s)",
        "detected makespan (s)",
        "detection stall (s)",
        "wasted gossip rounds",
    ]);
    let mut csv = String::from("rate,scheduled,detected,stall,wasted\n");
    let mut stalls = Vec::new();
    for rate in [0usize, 1, 2, 4] {
        let schedule = schedule_at_rate(rate);
        let sched = simulate_gossip(&schedule, false, payload, 7);
        let det = simulate_gossip(&schedule, true, payload, 7);
        assert_eq!(sched.detect_stall, 0.0, "scheduled churn never pays detection");
        assert!(
            det.detect_stall >= sched.detect_stall,
            "detection cannot be cheaper than an announcement"
        );
        table.row(&[
            rate.to_string(),
            format!("{:.1}", sched.makespan),
            format!("{:.1}", det.makespan),
            format!("{:.1}", det.detect_stall),
            det.wasted_rounds.to_string(),
        ]);
        csv.push_str(&format!(
            "{rate},{:.2},{:.2},{:.2},{}\n",
            sched.makespan, det.makespan, det.detect_stall, det.wasted_rounds
        ));
        stalls.push(det.detect_stall);
    }
    let md = table.to_markdown();
    println!(
        "## Churn-rate sweep — scheduled vs detected leaves \
         ({DETECT_MISSES} missed heartbeats to declare, {GOSSIP_TIMEOUT_SECS:.0}s timeout)\n\n{md}"
    );
    std::fs::write(format!("{out}/churn_rate.md"), &md)?;
    std::fs::write(format!("{out}/churn_rate.csv"), csv)?;
    assert!(
        stalls.last().unwrap() > stalls.first().unwrap(),
        "detection overhead must grow with the churn rate: {stalls:?}"
    );
    println!(
        "\nDetection costs exactly the undetected window: each unannounced leave burns up to \
         {DETECT_MISSES} gossip rounds of straggler timeouts before the survivors re-pair — \
         the price of needing no schedule.\n"
    );

    // ---- convergence under churn (Theorem-1 quadratic harness) ----
    let mut prng = Pcg64::seed_from_u64(5);
    let problem = Quadratic::new(8, 0.2, 1.0, 0.5, &mut prng);
    let quiet = quad_churn(&problem, 120, &ChurnSchedule::none(), 21);
    let churned = quad_churn(
        &problem,
        120,
        &ChurnSchedule::none().leave(30, 2).leave(30, 5).join(60, 2),
        21,
    );
    let mut table = Table::new(&["run", "final mean loss", "final replica var"]);
    table.row(&[
        "NoLoCo, static membership".into(),
        format!("{:.3e}", quiet.0),
        format!("{:.3e}", quiet.1),
    ]);
    table.row(&[
        "NoLoCo, leave x2 + rejoin".into(),
        format!("{:.3e}", churned.0),
        format!("{:.3e}", churned.1),
    ]);
    table.row(&[
        "DiLoCo / FSDP, any churn".into(),
        "aborts at first event".into(),
        "—".into(),
    ]);
    let md = table.to_markdown();
    println!("## Convergence under churn (quadratic, Theorem 1 setting)\n\n{md}");
    std::fs::write(format!("{out}/convergence.md"), &md)?;
    assert!(
        churned.0 < quiet.0 * 10.0 + 1e-3,
        "churned run must stay in the converged regime: {:.3e} vs {:.3e}",
        churned.0,
        quiet.0
    );
    println!(
        "\nGossip absorbed the churn: the rejoined replica adopted a donor's consensus \
         state and the run converged within an order of magnitude of the static one."
    );

    // ---- the real elastic trainer, when artifacts are available ----
    // Both executors now return the same unified TrainReport, so the
    // threaded run reports the identical shape (trace, comm counters,
    // per-step losses) the convergence experiments consume.
    match noloco::runtime::find_build("artifacts", "tiny", 2) {
        Ok(_) => {
            let mut cfg = presets::preset("tiny").unwrap();
            cfg.steps = 8;
            cfg.warmup = 2;
            cfg.eval_tokens = 512;
            cfg.outer.inner_steps = 2;
            cfg.churn = ChurnSchedule::none().leave(3, 1).join(5, 1);
            let report = noloco::train::run_threaded(&cfg)?;
            println!(
                "\n## Threaded elastic run (tiny artifacts, {} executor): final ppl {:.2}, \
                 {} gossip pairs / {} blocking collectives, {:.1} MiB on the fabric; \
                 losses finite on every step a replica was live",
                report.executor,
                report.final_val_ppl,
                report.comm.pair_exchanges,
                report.comm.blocking_collectives,
                report.comm.mib_sent(),
            );
        }
        Err(_) => println!(
            "\n(threaded elastic-trainer demo skipped: no tiny artifacts; run `make artifacts`)"
        ),
    }

    println!("\nwritten to {out}/completion.* and {out}/convergence.md");
    Ok(())
}
