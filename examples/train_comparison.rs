//! Table 2 + Figs. 2/3 driver: FSDP vs DiLoCo vs NoLoCo across datasets
//! and DP × PP topologies; final validation perplexities as a Markdown
//! table, and (with `--curves`) the per-step series that generate Fig. 2
//! (validation PPL curves), Fig. 3A (relative PPL difference, Eq. 4) and
//! Fig. 3B (normalized cross-replica weight σ).
//!
//! ```sh
//! cargo run --release --example train_comparison -- --preset tiny --out results/table2
//! cargo run --release --example train_comparison -- --curves --out results/fig2_3
//! ```
//!
//! Scale note (DESIGN.md §4): the paper's topologies (DP 4–16, PP 1–4,
//! 125M–6.8B params) are reproduced in *shape* at CPU scale — same
//! methods, same optimizer settings, smaller models and worker counts.

use noloco::cli::Args;
use noloco::config::{presets, Dataset, Method, TrainConfig};
use noloco::metrics::{rel_ppl_diff, Table};
use noloco::runtime::{find_build, Engine};
use noloco::train::{SimTrainer, TrainReport};

fn run_one(cfg: &TrainConfig, eng: &mut Engine) -> anyhow::Result<TrainReport> {
    SimTrainer::new(cfg.clone(), eng)?.run()
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1)).map_err(anyhow::Error::msg)?;
    let preset = args.opt("preset").unwrap_or("tiny");
    let out = args.opt("out").unwrap_or("results/table2").to_string();
    let curves = args.has_flag("curves");
    std::fs::create_dir_all(&out)?;

    let base = presets::preset(preset).expect("preset");
    let steps = args
        .opt_usize("steps")
        .map_err(anyhow::Error::msg)?
        .unwrap_or(if curves { 240 } else { 160 });
    // Scaled-down mirror of Table 2's topology column: (dp, pp). Curves
    // run at dp=4 so gossip pairs are proper subsets of the row and the
    // cross-replica σ of Fig. 3B stays meaningful at outer-step-aligned
    // eval points (at dp=2 the pair covers the whole world and σ
    // collapses there — the n=N degeneracy noted below Eq. 2).
    let topologies: &[(usize, usize)] = if curves {
        &[(4, 2)]
    } else {
        &[(2, 1), (2, 2), (4, 2)]
    };
    let datasets = [Dataset::RedditLike, Dataset::C4Like];
    let methods = [Method::Fsdp, Method::DiLoCo, Method::NoLoCo];

    let mut table = Table::new(&[
        "Dataset", "DP", "PP", "FSDP", "DiLoCo", "NoLoCo", "RelDiff(Eq.4)",
    ]);
    // One engine per pp value, reused across every run (compile once).
    for &(dp, pp) in topologies {
        let dir = find_build(&base.artifacts_dir, &base.model.name, pp)?;
        let mut eng = Engine::new(dir)?;
        for ds in datasets {
            let mut ppl = std::collections::BTreeMap::new();
            for method in methods {
                let mut cfg = match method {
                    Method::Fsdp => presets::as_fsdp(base.clone()),
                    Method::DiLoCo => presets::as_diloco(base.clone()),
                    Method::NoLoCo => base.clone(),
                };
                cfg.topology.dp = dp;
                cfg.topology.pp = pp;
                cfg.dataset = ds;
                cfg.steps = steps;
                cfg.warmup = steps / 8;
                // Paper cadence scaled: NoLoCo outer every 10, DiLoCo every
                // 20 (keeping the 2x frequency relationship of §4).
                cfg.outer.inner_steps = match method {
                    Method::DiLoCo => 20,
                    _ => 10,
                };
                // Batch must cover dp replicas x the artifact microbatch.
                cfg.model.batch_tokens =
                    cfg.model.batch_tokens.max(dp * 2 * cfg.model.seq_len);
                cfg.eval_every = if curves { 10 } else { 0 };
                let t0 = std::time::Instant::now();
                let report = run_one(&cfg, &mut eng)?;
                println!(
                    "{ds} dp={dp} pp={pp} {method}: ppl {:.2} ({:.0}s, {} execs)",
                    report.final_val_ppl,
                    t0.elapsed().as_secs_f64(),
                    report.executions
                );
                if curves {
                    report
                        .trace
                        .write_csv(&format!("{out}/curve_{ds}_{method}_dp{dp}_pp{pp}.csv"))?;
                }
                ppl.insert(method.to_string(), report.final_val_ppl);
            }
            let (f, d, n) = (ppl["FSDP"], ppl["DiLoCo"], ppl["NoLoCo"]);
            table.row(&[
                ds.to_string(),
                dp.to_string(),
                pp.to_string(),
                format!("{f:.2}"),
                format!("{d:.2}"),
                format!("{n:.2}"),
                format!("{:+.3}", rel_ppl_diff(d, n, f)),
            ]);
        }
    }

    let md = table.to_markdown();
    println!("\n## Table 2 (CPU-scale reproduction)\n\n{md}");
    std::fs::write(format!("{out}/table2.md"), md)?;
    println!("written to {out}/table2.md");
    if curves {
        println!("per-method curves in {out}/curve_*.csv (Fig. 2, 3A, 3B inputs)");
    }
    Ok(())
}
