//! Design-choice ablations the paper calls out but does not sweep —
//! DESIGN.md §5.3: γ (the consensus coefficient), the outer-step cadence
//! m, and the gossip group size n, all on the real LM training stack.
//!
//! ```sh
//! cargo run --release --example ablations -- --out results/ablations [--steps N]
//! ```
//!
//! * **γ sweep** — Eq. 74 predicts a stability window
//!   `sqrt(n/2(n-1))·α < γ < sqrt(n/2(n-1)·(2+α²))`; outside it the
//!   ensemble variance grows. Swept across the window on the LM.
//! * **m sweep** — outer cadence: the paper uses 50 (NoLoCo) vs 100
//!   (DiLoCo). More frequent gossip → tighter ensemble, more comm.
//! * **n sweep** — gossip group size (§3.2's general form): larger
//!   groups interpolate toward DiLoCo's all-reduce.

use noloco::cli::Args;
use noloco::config::{presets, OuterConfig};
use noloco::metrics::Table;
use noloco::runtime::{find_build, Engine};
use noloco::train::SimTrainer;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1)).map_err(anyhow::Error::msg)?;
    let out = args.opt("out").unwrap_or("results/ablations").to_string();
    let steps = args
        .opt_usize("steps")
        .map_err(anyhow::Error::msg)?
        .unwrap_or(120);
    std::fs::create_dir_all(&out)?;

    let mut base = presets::preset("tiny").expect("preset");
    base.steps = steps;
    base.warmup = steps / 8;
    base.eval_every = 0;
    base.outer.inner_steps = 10;
    base.topology.dp = 4;
    base.topology.pp = 2;
    // dp=4 needs 4 x mb=2 seqs per step.
    base.model.batch_tokens = 4 * 2 * base.model.seq_len;

    let dir = find_build(&base.artifacts_dir, &base.model.name, 2)?;
    let mut eng = Engine::new(dir)?;

    // ---- γ sweep (within the Eq. 74 window; the out-of-window failure
    // mode is demonstrated on the quadratic harness, where the config
    // validator does not apply — see examples/quadratic_convergence.rs) ----
    let (lo, hi) = OuterConfig::gamma_window(base.outer.alpha, 2);
    println!("## γ sweep (window: {lo:.3} .. {hi:.3})\n");
    let mut t = Table::new(&["γ", "val ppl", "final weight σ"]);
    let mut csv = String::from("gamma,ppl,sigma\n");
    for &g in &[lo * 1.02, lo + 0.25 * (hi - lo), 0.5 * (lo + hi), hi * 0.98] {
        let mut cfg = base.clone();
        cfg.outer.gamma = g;
        let mut trainer = SimTrainer::new(cfg, &mut eng)?;
        let report = trainer.run()?;
        let sigma = trainer.weight_std();
        println!("γ={g:.3}: ppl {:.2}, σ {:.5}", report.final_val_ppl, sigma);
        t.row(&[
            format!("{g:.3}"),
            format!("{:.2}", report.final_val_ppl),
            format!("{sigma:.5}"),
        ]);
        csv.push_str(&format!("{g:.4},{:.4},{sigma:.6}\n", report.final_val_ppl));
    }
    std::fs::write(format!("{out}/gamma_sweep.md"), t.to_markdown())?;
    std::fs::write(format!("{out}/gamma_sweep.csv"), csv)?;

    // ---- m (outer cadence) sweep ----
    println!("\n## outer-cadence sweep (m = inner steps per outer step)\n");
    let mut t = Table::new(&["m", "val ppl", "final weight σ", "gossip pairs"]);
    let mut csv = String::from("m,ppl,sigma,pairs\n");
    for &m in &[5usize, 10, 20, 40] {
        let mut cfg = base.clone();
        cfg.outer.inner_steps = m;
        let mut trainer = SimTrainer::new(cfg, &mut eng)?;
        let report = trainer.run()?;
        let sigma = trainer.weight_std();
        println!(
            "m={m}: ppl {:.2}, σ {:.5}, pairs {}",
            report.final_val_ppl, sigma, report.comm.pair_exchanges
        );
        t.row(&[
            m.to_string(),
            format!("{:.2}", report.final_val_ppl),
            format!("{sigma:.5}"),
            report.comm.pair_exchanges.to_string(),
        ]);
        csv.push_str(&format!(
            "{m},{:.4},{sigma:.6},{}\n",
            report.final_val_ppl, report.comm.pair_exchanges
        ));
    }
    std::fs::write(format!("{out}/cadence_sweep.md"), t.to_markdown())?;
    std::fs::write(format!("{out}/cadence_sweep.csv"), csv)?;

    // ---- n (group size) sweep ----
    println!("\n## gossip group-size sweep (n = 4 ≙ whole row = DiLoCo-like)\n");
    let mut t = Table::new(&["n", "val ppl", "final weight σ", "floats/outer-step"]);
    let mut csv = String::from("n,ppl,sigma,floats\n");
    for &n in &[2usize, 4] {
        let mut cfg = base.clone();
        cfg.outer.group = n;
        cfg.outer.gamma = OuterConfig::default_gamma(cfg.outer.alpha, n);
        let mut trainer = SimTrainer::new(cfg, &mut eng)?;
        let report = trainer.run()?;
        let sigma = trainer.weight_std();
        // Total payload per outer step (activations included; the sync
        // share scales as n(n-1) within each group).
        let outer_steps = (steps / base.outer.inner_steps) as u64;
        let floats = report.comm.floats_sent / outer_steps.max(1);
        println!(
            "n={n}: ppl {:.2}, σ {:.5}, pairs {}",
            report.final_val_ppl, sigma, report.comm.pair_exchanges
        );
        t.row(&[
            n.to_string(),
            format!("{:.2}", report.final_val_ppl),
            format!("{sigma:.5}"),
            floats.to_string(),
        ]);
        csv.push_str(&format!("{n},{:.4},{sigma:.6},{floats}\n", report.final_val_ppl));
    }
    std::fs::write(format!("{out}/group_sweep.md"), t.to_markdown())?;
    std::fs::write(format!("{out}/group_sweep.csv"), csv)?;

    println!("\nwritten to {out}/");
    Ok(())
}
