//! Theorem 1 driver — convergence of the modified Nesterov outer
//! optimizer on the stochastic quadratic loss (App. A setup):
//! `L(θ) = ½(θ−c)ᵀA(θ−c)`, `c ~ N(0, Σ)`.
//!
//! Regenerates the three theoretical claims:
//!
//! 1. `E(φ_t) → 0` as outer steps grow (Theorem 2);
//! 2. `V(φ_t) ∝ ω²` — replica variance at convergence scales with the
//!    *square* of the inner learning rate (Theorem 3), the property that
//!    makes LR schedules an eventual-consistency knob (§5.1, Fig. 3B);
//! 3. the γ stability window of Eq. 74.
//!
//! ```sh
//! cargo run --release --example quadratic_convergence -- --out results/thm1
//! ```

use noloco::cli::Args;
use noloco::config::{Method, OuterConfig};
use noloco::metrics::Table;
use noloco::quad::{run_noloco, QuadSim, Quadratic};
use noloco::rngx::Pcg64;

fn sim(omega: f64, gamma: f64, replicas: usize, outer_steps: usize) -> QuadSim {
    QuadSim {
        replicas,
        inner_steps: 10,
        outer_steps,
        omega,
        outer: OuterConfig {
            method: Method::NoLoCo,
            alpha: 0.5,
            beta: 0.7,
            gamma,
            group: 2,
            inner_steps: 10,
            staleness: 1,
        },
        init_scale: 2.0,
    }
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1)).map_err(anyhow::Error::msg)?;
    let out = args.opt("out").unwrap_or("results/thm1").to_string();
    std::fs::create_dir_all(&out)?;

    let mut rng = Pcg64::seed_from_u64(42);
    let problem = Quadratic::new(10, 0.2, 1.0, 0.5, &mut rng);
    let gamma = OuterConfig::default_gamma(0.5, 2);

    // ---- Claim 1: E(phi) -> 0 ----
    println!("## Theorem 2 — E(φ_t) → 0\n");
    let res = run_noloco(&problem, &sim(0.05, gamma, 16, 300), 7);
    let mut csv = String::from("outer_step,mean_norm,replica_var\n");
    for (i, (mn, rv)) in res.mean_norm.iter().zip(&res.replica_var).enumerate() {
        csv.push_str(&format!("{i},{mn:.6e},{rv:.6e}\n"));
    }
    std::fs::write(format!("{out}/trajectory.csv"), csv)?;
    for &t in &[0usize, 10, 50, 100, 200, 299] {
        println!("  t={t:>4}  ‖E(φ)‖ = {:.4e}  V(φ) = {:.4e}", res.mean_norm[t], res.replica_var[t]);
    }
    // With a *stochastic* loss and finitely many replicas, ‖mean φ‖
    // floors at the sampling noise ~ sqrt(V/N) rather than exactly 0;
    // measure the decay from the initial distance.
    let decay = res.mean_norm[299] / res.mean_norm[0];
    let noise_floor =
        (res.replica_var[299] * problem.dim as f64 / 16.0).sqrt();
    println!(
        "  decay from init: {decay:.2e} (must be << 1); final ‖E(φ)‖ {:.3e} vs sampling floor {:.3e}",
        res.mean_norm[299], noise_floor
    );
    assert!(decay < 0.02);
    assert!(res.mean_norm[299] < 6.0 * noise_floor);

    // ---- Claim 2: V(phi) ∝ ω² ----
    println!("\n## Theorem 3 — V(φ) ∝ ω²\n");
    let mut table = Table::new(&["ω", "V(φ) tail mean", "V/ω²"]);
    let mut csv = String::from("omega,variance,v_over_omega_sq\n");
    for &omega in &[0.02f64, 0.04, 0.08, 0.16] {
        let res = run_noloco(&problem, &sim(omega, gamma, 16, 400), 11);
        let tail = &res.replica_var[320..];
        let v = tail.iter().sum::<f64>() / tail.len() as f64;
        table.row(&[
            format!("{omega}"),
            format!("{v:.4e}"),
            format!("{:.4}", v / (omega * omega)),
        ]);
        csv.push_str(&format!("{omega},{v:.6e},{:.4}\n", v / (omega * omega)));
    }
    println!("{}", table.to_markdown());
    println!("(V/ω² roughly constant across a 8x ω range ⇒ V ∝ ω².)");
    std::fs::write(format!("{out}/variance_scaling.csv"), csv)?;

    // ---- Claim 3: the Eq. 74 γ window ----
    println!("\n## Eq. 74 — γ stability window (α=0.5, n=2: 0.5 < γ < 1.5)\n");
    let (lo, hi) = OuterConfig::gamma_window(0.5, 2);
    let mut table = Table::new(&["γ", "position", "final V(φ)", "final loss"]);
    let mut csv = String::from("gamma,variance,loss\n");
    for &(g, pos) in &[
        (lo * 0.1, "far below"),
        (lo * 0.9, "just below"),
        (0.5 * (lo + hi), "inside"),
        (hi * 0.98, "near top"),
    ] {
        let res = run_noloco(&problem, &sim(0.08, g, 16, 250), 3);
        let tail = &res.replica_var[200..];
        let v = tail.iter().sum::<f64>() / tail.len() as f64;
        table.row(&[
            format!("{g:.3}"),
            pos.to_string(),
            format!("{v:.4e}"),
            format!("{:.4e}", res.final_loss),
        ]);
        csv.push_str(&format!("{g:.4},{v:.6e},{:.6e}\n", res.final_loss));
    }
    println!("{}", table.to_markdown());
    println!("(γ below the window loses the consensus contraction → larger ensemble variance.)");
    std::fs::write(format!("{out}/gamma_window.csv"), csv)?;

    println!("\nwritten to {out}/");
    Ok(())
}
