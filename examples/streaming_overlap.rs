//! Streaming fragmented outer sync — how much synchronization wall-clock
//! the overlap hides.
//!
//! Two views of the same question:
//!
//! * **Cost model** (always runs): on the `wan` preset, compare the gated
//!   outer sync (the full (Δ, φ) pair exchange gating every boundary)
//!   against the streamed residual — each of `K` fragments exchanged
//!   behind one inner phase, only `max(0, t_k − compute)` left visible —
//!   sweeping the fragment count. The *hiding ratio* `1 − residual/gated`
//!   is the fraction of sync time removed from the critical path.
//! * **Real trainer** (when the tiny artifact build exists): run gated
//!   NoLoCo and `--sync streaming` side by side and show the identical
//!   report shape, finite losses, and the unchanged collective-free
//!   communication profile.
//!
//! ```sh
//! cargo run --release --example streaming_overlap -- --out results/streaming
//! ```

use noloco::bench::gated_vs_streamed_pair_sync;
use noloco::cli::Args;
use noloco::config::{presets, NetPreset, NetTopoConfig, StreamConfig, SyncMode};
use noloco::metrics::Table;

const DP: usize = 24;
/// Both directions of (Δ, φ) at `small`-model scale.
const PAYLOAD: u64 = 2 * (4 << 20);
/// Virtual seconds of inner compute behind each fragment (~one phase).
const COMPUTE: f64 = 0.5;
const ROUNDS: u64 = 100;

fn wan() -> NetTopoConfig {
    NetTopoConfig {
        preset: NetPreset::MultiRegionWan,
        regions: 3,
        ..NetTopoConfig::default()
    }
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1)).map_err(anyhow::Error::msg)?;
    let out = args.opt("out").unwrap_or("results/streaming").to_string();
    std::fs::create_dir_all(&out)?;

    println!(
        "## Streamed outer sync on the wan preset — {DP} replicas, \
         {:.0} MiB (Δ, φ), {COMPUTE}s inner phase per fragment\n",
        PAYLOAD as f64 / (1024.0 * 1024.0)
    );

    let mut table = Table::new(&["fragments", "gated (s)", "streamed residual (s)", "hiding"]);
    let mut csv = String::from("fragments,gated_secs,residual_secs,hiding_ratio\n");
    let mut last_resid = f64::INFINITY;
    for fragments in [1usize, 2, 4, 8] {
        let (gated, resid) =
            gated_vs_streamed_pair_sync(&wan(), DP, PAYLOAD, fragments, COMPUTE, ROUNDS);
        let hiding = 1.0 - resid / gated;
        table.row(&[
            fragments.to_string(),
            format!("{gated:.3}"),
            format!("{resid:.3}"),
            format!("{:.1}%", hiding * 100.0),
        ]);
        csv.push_str(&format!("{fragments},{gated:.4},{resid:.4},{hiding:.4}\n"));
        assert!(
            resid < gated,
            "streamed residual must undercut the gated sync: {resid} vs {gated}"
        );
        assert!(
            resid <= last_resid * 1.05,
            "finer fragments must not raise the residual materially"
        );
        last_resid = resid;
    }
    let md = table.to_markdown();
    println!("{md}");
    std::fs::write(format!("{out}/hiding.md"), &md)?;
    std::fs::write(format!("{out}/hiding.csv"), csv)?;
    println!(
        "Splitting the exchange lets each chunk ride behind an inner phase: the \
         serialization term divides by K while the per-fragment latency stays \
         below the phase length, so the visible sync cost collapses.\n"
    );

    // ---- the real trainer, when artifacts are available ----
    match noloco::runtime::find_build("artifacts", "tiny", 2) {
        Ok(_) => {
            let mut cfg = presets::preset("tiny").unwrap();
            cfg.steps = 8;
            cfg.warmup = 2;
            cfg.eval_tokens = 512;
            cfg.outer.inner_steps = 2;
            let gated = noloco::train::run_sim(&cfg)?;
            cfg.sync = SyncMode::Streaming;
            cfg.stream = StreamConfig { fragments: 2, overlap: true, ..StreamConfig::default() };
            let streamed = noloco::train::run_sim(&cfg)?;
            println!(
                "## Trainer check (tiny artifacts): gated ppl {:.2} vs streamed ppl {:.2}; \
                 both collective-free ({} / {} blocking collectives), streamed sends the \
                 same exchanges in {}-fragment slices",
                gated.final_val_ppl,
                streamed.final_val_ppl,
                gated.comm.blocking_collectives,
                streamed.comm.blocking_collectives,
                cfg.stream.fragments,
            );
        }
        Err(_) => println!(
            "(trainer check skipped: no tiny artifacts; run `make artifacts`)"
        ),
    }

    println!("\nwritten to {out}/hiding.*");
    Ok(())
}
