//! Fig. 4 driver — effect of random pipeline routing, isolated.
//!
//! Reproduces the paper's §5.2 ablation: **no outer optimizer steps at
//! all** (so DP replicas never synchronize explicitly), comparing random
//! vs fixed routing. With fixed routing the replicas are fully independent
//! training runs; with random routing they mix only through the pipeline.
//! Reported per eval point, as in the paper:
//!
//! * Fig. 4A — ratio of cross-replica weight σ (random / fixed) — the
//!   paper sees ~0.85–0.9 (random routing reduces divergence);
//! * Fig. 4B — ratio of validation perplexity (random / fixed) — the
//!   paper sees ≥ 1 (random routing slightly hinders loss convergence).
//!
//! ```sh
//! cargo run --release --example routing_ablation -- --preset tiny --out results/fig4
//! ```

use noloco::cli::Args;
use noloco::config::{presets, Routing};
use noloco::metrics::Table;
use noloco::runtime::{find_build, Engine};
use noloco::train::{SimTrainer, TrainReport};

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1)).map_err(anyhow::Error::msg)?;
    let preset = args.opt("preset").unwrap_or("tiny");
    let out = args.opt("out").unwrap_or("results/fig4").to_string();
    let steps = args
        .opt_usize("steps")
        .map_err(anyhow::Error::msg)?
        .unwrap_or(200);
    std::fs::create_dir_all(&out)?;

    let mut cfg = presets::preset(preset).expect("preset");
    cfg.steps = steps;
    cfg.warmup = steps / 8;
    cfg.eval_every = (steps / 12).max(1);
    // The ablation's key setting: outer steps never fire.
    cfg.outer.inner_steps = steps + 1;
    cfg.topology.dp = 2;
    cfg.topology.pp = 2;

    let dir = find_build(&cfg.artifacts_dir, &cfg.model.name, cfg.topology.pp)?;
    let mut eng = Engine::new(dir)?;

    let mut run = |routing: Routing| -> anyhow::Result<TrainReport> {
        let mut c = cfg.clone();
        c.routing = routing;
        let t0 = std::time::Instant::now();
        let r = SimTrainer::new(c, &mut eng)?.run()?;
        println!(
            "{routing:?}: final ppl {:.2}, final σ {:.5} ({:.0}s)",
            r.final_val_ppl,
            r.trace.weight_std.last().copied().unwrap_or(0.0),
            t0.elapsed().as_secs_f64()
        );
        Ok(r)
    };

    let random = run(Routing::Random)?;
    let fixed = run(Routing::Fixed)?;

    let mut table = Table::new(&[
        "step", "σ random", "σ fixed", "σ ratio (Fig4A)", "ppl random", "ppl fixed",
        "ppl ratio (Fig4B)",
    ]);
    let mut csv = String::from("step,sigma_ratio,ppl_ratio\n");
    let n = random.trace.steps.len().min(fixed.trace.steps.len());
    for i in 0..n {
        let sr = random.trace.weight_std[i];
        let sf = fixed.trace.weight_std[i];
        let pr = random.trace.val_loss[i].exp();
        let pf = fixed.trace.val_loss[i].exp();
        let s_ratio = if sf > 0.0 { sr / sf } else { f64::NAN };
        let p_ratio = pr / pf;
        table.row(&[
            random.trace.steps[i].to_string(),
            format!("{sr:.5}"),
            format!("{sf:.5}"),
            format!("{s_ratio:.3}"),
            format!("{pr:.2}"),
            format!("{pf:.2}"),
            format!("{p_ratio:.3}"),
        ]);
        csv.push_str(&format!("{},{s_ratio:.4},{p_ratio:.4}\n", random.trace.steps[i]));
    }
    let md = table.to_markdown();
    println!("\n## Fig. 4 — routing ablation (no outer sync)\n\n{md}");
    std::fs::write(format!("{out}/fig4.md"), &md)?;
    std::fs::write(format!("{out}/fig4.csv"), csv)?;

    // Paper-shape summary over the latter half of training.
    let half = n / 2;
    let mean = |xs: &[f64]| xs.iter().sum::<f64>() / xs.len().max(1) as f64;
    let s_ratio_late: Vec<f64> = (half..n)
        .filter(|&i| fixed.trace.weight_std[i] > 0.0)
        .map(|i| random.trace.weight_std[i] / fixed.trace.weight_std[i])
        .collect();
    let p_ratio_late: Vec<f64> = (half..n)
        .map(|i| (random.trace.val_loss[i] - fixed.trace.val_loss[i]).exp())
        .collect();
    println!(
        "\nlate-training means: σ ratio {:.3} (paper: ~0.85–0.90), ppl ratio {:.3} (paper: ~1.0–1.04)",
        mean(&s_ratio_late),
        mean(&p_ratio_late)
    );
    println!("written to {out}/fig4.md and {out}/fig4.csv");
    Ok(())
}
