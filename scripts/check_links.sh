#!/usr/bin/env bash
# Lightweight markdown link checker for the docs layer.
#
# Verifies that every relative link/image target in the checked files
# exists on disk (anchors and external http(s)/mailto links are skipped —
# no network access in CI). Also verifies that paths named in backticks
# with a known docs prefix exist, so README references like
# `docs/ARCHITECTURE.md` cannot rot.
#
# Usage: scripts/check_links.sh [files...]   (defaults to README.md docs/*.md)

set -u
cd "$(dirname "$0")/.."

files=("$@")
if [ ${#files[@]} -eq 0 ]; then
    files=(README.md docs/*.md)
fi

fail=0

check_target() {
    # $1 = referencing file, $2 = raw target
    local src="$1" target="$2"
    case "$target" in
        http://*|https://*|mailto:*|\#*) return 0 ;;
    esac
    target="${target%%#*}"              # strip in-page anchors
    [ -z "$target" ] && return 0
    local base
    case "$target" in
        /*) base=".$target" ;;
        *)  base="$(dirname "$src")/$target" ;;
    esac
    if [ ! -e "$base" ]; then
        echo "BROKEN LINK: $src -> $target"
        fail=1
    fi
}

for f in "${files[@]}"; do
    if [ ! -f "$f" ]; then
        echo "MISSING FILE: $f"
        fail=1
        continue
    fi
    # Markdown links and images: [text](target), ![alt](target)
    while IFS= read -r target; do
        check_target "$f" "$target"
    done < <(grep -o '!\?\[[^]]*\]([^)]*)' "$f" | sed 's/.*](\([^)]*\))/\1/')
    # Backticked repo paths with a known prefix: `docs/...`, `rust/...`,
    # `python/...`, `examples/...`, `scripts/...` — always repo-root
    # relative, wherever they are referenced from.
    while IFS= read -r target; do
        # Skip glob-y or placeholder paths.
        case "$target" in
            *\**|*\<*|*\$*) continue ;;
        esac
        check_target "$f" "/$target"
    done < <(grep -o '`\(docs\|rust\|python\|examples\|scripts\)/[^`]*`' "$f" | tr -d '`')
done

if [ "$fail" -ne 0 ]; then
    echo "docs link check FAILED"
    exit 1
fi
echo "docs link check OK (${files[*]})"
