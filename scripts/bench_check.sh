#!/usr/bin/env bash
# Cost-model + scale-ladder regression gate against the checked-in
# BENCH_baseline.json and BENCH_steps.json.
#
# Recomputes the deterministic expected-time baselines and the
# 64/256/1000-replica scale ladder (see rust/src/obs/bench.rs) and fails
# when any metric drifts more than 10% from the committed values. With a
# Rust toolchain the live numbers come from `cargo run -- bench-baseline`
# and `cargo run -- perf`; without one, from the Python mirrors below,
# which re-implement the same closed-form arithmetic (log-normal
# expected latencies, heap-tree / ring walks, the ladder's throughput /
# bytes / residency forms) — change them together with
# rust/src/obs/bench.rs.
#
# Usage: scripts/bench_check.sh [--update]
#   --update   rewrite BENCH_baseline.json + BENCH_steps.json with the
#              live values

set -u
cd "$(dirname "$0")/.."

BASELINE="BENCH_baseline.json"
STEPS="BENCH_steps.json"
update=0
if [ "${1:-}" = "--update" ]; then
    update=1
fi

live="$(mktemp -t noloco_bench_XXXXXX.json)"
live_steps="$(mktemp -t noloco_steps_XXXXXX.json)"
trap 'rm -f "$live" "$live_steps"' EXIT

mirror() {
    python3 - <<'PY'
import json
import math

# Mirror of the constants + walks in rust/src/obs/bench.rs over the
# NetTopoConfig defaults in rust/src/config/mod.rs.
WORLD = 24
BYTES = 8 * 1024 * 1024
OUTER_BYTES = 8_000_000
FRAGMENTS = 4
STREAM_COMPUTE_S = 0.5

INTRA_LAT, INTER_LAT, POD_LAT = 1e-3, 80e-3, 5e-3
INTRA_BW, INTER_BW, POD_BW = 1.25e9, 1.25e7, 1.25e8
SIGMA = 0.6
RACKS_PER_POD = 2
LOGN = math.exp(SIGMA * SIGMA / 2.0)  # E[LogNormal(ln m, s^2)] = m * e^(s^2/2)


def lan_link(a, b):
    return (INTRA_LAT, INTRA_BW)


def wan_link(a, b):
    # 24 nodes over 3 regions of 8.
    if a // 8 == b // 8:
        return (INTRA_LAT * LOGN, INTRA_BW)
    return (INTER_LAT * LOGN, INTER_BW)


def hier_link(a, b):
    # 3 pods x 2 racks = 6 racks of 4; rack = i // 4, pod = rack // 2.
    ra, rb = a // 4, b // 4
    if ra == rb:
        return (INTRA_LAT, INTRA_BW)
    if ra // RACKS_PER_POD == rb // RACKS_PER_POD:
        return (POD_LAT, POD_BW)
    return (INTER_LAT * LOGN, INTER_BW)


def expected(link, a, b, nbytes):
    lat, bw = link(a, b)
    return lat + nbytes / bw


PAIRS = [(2 * i, 2 * i + 1) for i in range(WORLD // 2)]


def pair_mean(link, nbytes):
    return sum(expected(link, a, b, nbytes) for a, b in PAIRS) / len(PAIRS)


def tree_allreduce(link, nbytes):
    n = WORLD
    ready = [0.0] * n
    for r in reversed(range(n)):  # reduce up the heap tree
        for c in (2 * r + 1, 2 * r + 2):
            if c < n:
                ready[r] = max(ready[r], ready[c] + expected(link, c, r, nbytes))
    for r in range(1, n):  # broadcast back down
        p = (r - 1) // 2
        ready[r] = max(ready[r], ready[p] + expected(link, p, r, nbytes))
    return max(ready)


def ring_allreduce(link, nbytes):
    n = WORLD
    chunk = -(-nbytes // n)
    ready = [0.0] * n
    for _ in range(2 * (n - 1)):
        start = ready[:]
        for r in range(n):
            to = (r + 1) % n
            ready[to] = max(start[to], start[r] + expected(link, r, to, chunk))
    return max(ready)


def streamed_residual(link, nbytes):
    chunk = -(-nbytes // FRAGMENTS)
    acc = 0.0
    for a, b in PAIRS:
        acc += max(expected(link, a, b, chunk) - STREAM_COMPUTE_S, 0.0) * FRAGMENTS
    return acc / len(PAIRS)


def boundary_idle(link, nbytes):
    computes = [0.25 + 0.05 * (w % 7) for w in range(WORLD)]
    done = computes[:]
    for a, b in PAIRS:
        t = max(computes[a], computes[b]) + expected(link, a, b, nbytes)
        done[a] = done[b] = t
    barrier = max(done)
    lock = sum(barrier - c for c in computes) / WORLD
    asy = sum(d - c for d, c in zip(done, computes)) / WORLD
    return lock, asy


out = {}
for name, link in (("lan", lan_link), ("wan", wan_link), ("hier", hier_link)):
    out[f"{name}.pair_mean_s"] = pair_mean(link, BYTES)
    out[f"{name}.tree_allreduce_s"] = tree_allreduce(link, BYTES)
    out[f"{name}.ring_allreduce_s"] = ring_allreduce(link, BYTES)
    out[f"{name}.streamed_residual_s"] = streamed_residual(link, BYTES)
    lock, asy = boundary_idle(link, BYTES)
    out[f"{name}.lockstep_idle_s"] = lock
    out[f"{name}.async_idle_s"] = asy
pair = pair_mean(wan_link, OUTER_BYTES)
tree = tree_allreduce(wan_link, OUTER_BYTES)
out["outer.noloco_pair_s"] = pair
out["outer.diloco_tree_s"] = tree
out["outer.speedup"] = tree / pair

# Socket transport on localhost (the CI loopback smoke shape): one
# symmetric framed gossip pair over the modeled kernel loopback hop.
LOOPBACK_LATENCY_S = 50e-6
LOOPBACK_BANDWIDTH = 12.5e9
FRAME_HEADER_BYTES = 8
out["socket.loopback_pair_s"] = 2.0 * (
    LOOPBACK_LATENCY_S + (OUTER_BYTES + FRAME_HEADER_BYTES) / LOOPBACK_BANDWIDTH
)

print(json.dumps({"v": 1, "metrics": out}, separators=(",", ":")))
PY
}

# Mirror of the scale-ladder closed forms in rust/src/obs/bench.rs
# (steps_ladder): fleet steps/sec, wire bytes per boundary, modeled
# peak RSS at dp = 64 / 256 / 1000 replicas.
mirror_steps() {
    python3 - <<'PY'
import json

LADDER = (64, 256, 1000)
PARAMS = 2 * 1024 * 1024      # outer-state floats per replica (8 MiB)
INNER = 50                    # inner steps per boundary (H)
COMPUTE_S = 0.02              # modeled fwd+bwd+Adam seconds per inner step
LINK_LATENCY_S = 1e-3         # gossip link latency (LAN intra-switch)
LINK_BANDWIDTH = 1.25e9       # gossip link bandwidth (bytes/s)

pair_s = LINK_LATENCY_S + (8 * PARAMS) / LINK_BANDWIDTH

out = {}
for dp in LADDER:
    out[f"steps.dp{dp}.steps_per_sec"] = dp / (COMPUTE_S + pair_s / INNER)
    out[f"steps.dp{dp}.bytes_per_boundary"] = float(dp * 2 * 4 * PARAMS)
    out[f"steps.dp{dp}.peak_rss_mib"] = ((6 * dp + 2) * 4 * PARAMS) / (1024.0 * 1024.0)

print(json.dumps({"v": 1, "metrics": out}, separators=(",", ":")))
PY
}

if command -v cargo >/dev/null 2>&1; then
    if ! (cd rust && cargo run --release --quiet -- bench-baseline --out "$live" >/dev/null); then
        echo "bench check FAILED (bench-baseline did not run)"
        exit 1
    fi
    if ! (cd rust && cargo run --release --quiet -- perf --out "$live_steps" >/dev/null); then
        echo "bench check FAILED (perf ladder did not run)"
        exit 1
    fi
    src="cargo run -- bench-baseline / perf"
else
    if ! mirror >"$live"; then
        echo "bench check FAILED (python mirror did not run)"
        exit 1
    fi
    if ! mirror_steps >"$live_steps"; then
        echo "bench check FAILED (python steps mirror did not run)"
        exit 1
    fi
    src="python mirror of rust/src/obs/bench.rs"
fi

if [ "$update" -eq 1 ]; then
    cp "$live" "$BASELINE"
    cp "$live_steps" "$STEPS"
    echo "bench baselines updated ($BASELINE + $STEPS from $src)"
    exit 0
fi

for f in "$BASELINE" "$STEPS"; do
    if [ ! -f "$f" ]; then
        echo "bench check FAILED ($f missing; run scripts/bench_check.sh --update)"
        exit 1
    fi
done

compare() {
    python3 - "$1" "$2" <<'PY'
import json
import sys

TOLERANCE = 0.10

base = json.load(open(sys.argv[1]))
live = json.load(open(sys.argv[2]))
fail = 0
if base.get("v") != 1 or live.get("v") != 1:
    print(f"unknown baseline version: base {base.get('v')!r} live {live.get('v')!r}")
    sys.exit(1)
bm, lm = base["metrics"], live["metrics"]
for k in sorted(set(bm) | set(lm)):
    if k not in bm or k not in lm:
        where = "baseline" if k not in bm else "live walk"
        print(f"MISSING METRIC: {k} absent from {where}")
        fail = 1
        continue
    b, l = float(bm[k]), float(lm[k])
    drift = abs(l - b) / max(abs(b), 1e-12)
    if drift > TOLERANCE:
        print(f"REGRESSION: {k}: baseline {b} vs live {l} ({100 * drift:.1f}% drift)")
        fail = 1
sys.exit(fail)
PY
}

if ! compare "$BASELINE" "$live"; then
    echo "bench check FAILED ($src vs $BASELINE)"
    exit 1
fi
if ! compare "$STEPS" "$live_steps"; then
    echo "bench check FAILED ($src vs $STEPS)"
    exit 1
fi
echo "bench check OK ($src vs $BASELINE + $STEPS, tolerance 10%)"
