#!/usr/bin/env bash
# Validate a run-journal JSONL against the obs schema (v1).
#
# With a Rust toolchain available: emits a fresh smoke journal via
# `cargo run -- obs-smoke` and validates that, so the journal writer and
# the schema table cannot drift apart unnoticed. Without one (minimal
# containers): validates the checked-in `docs/trace.sample.jsonl`
# instead. Pass a journal path to validate an arbitrary run's trace
# (per-event coverage is then not required — a clean run has no
# `hb_miss`/`detect` lines).
#
# The REQUIRED table below mirrors `required_keys` in
# rust/src/obs/journal.rs — change them together.
#
# Usage: scripts/check_trace_schema.sh [journal.jsonl]

set -u
cd "$(dirname "$0")/.."

journal="${1:-}"
coverage="partial"
cleanup=""
if [ -z "$journal" ]; then
    coverage="full"
    if command -v cargo >/dev/null 2>&1; then
        journal="$(mktemp -t noloco_trace_XXXXXX.jsonl)"
        cleanup="$journal"
        if ! (cd rust && cargo run --release --quiet -- obs-smoke --out "$journal" >/dev/null); then
            echo "trace schema check FAILED (obs-smoke did not run)"
            rm -f "$cleanup"
            exit 1
        fi
    else
        journal="docs/trace.sample.jsonl"
        echo "no cargo toolchain; validating checked-in $journal"
    fi
fi

python3 - "$journal" "$coverage" <<'PY'
import json
import sys

# Mirror of required_keys() in rust/src/obs/journal.rs.
REQUIRED = {
    "analyze": ["version", "findings", "clean"],
    "inner": ["stage", "replica", "step", "loss", "dur_s"],
    "offer": ["stage", "replica", "peer", "round", "frag", "bytes"],
    "fold": ["stage", "replica", "peer", "round", "frag", "age", "bytes"],
    "hb_miss": ["stage", "replica", "peer", "boundary"],
    "detect": ["boundary", "node", "join"],
    "churn": ["step", "node", "join"],
    "sweep": ["boundary", "dropped"],
    "boundary": ["outer_idx", "inner_s", "sync_s", "bytes", "msgs"],
    "drain": ["outer_idx", "bytes", "msgs"],
    "ckpt": ["boundary", "step", "bytes"],
    "resume": ["boundary", "step"],
    "net_peer": ["peer", "bytes", "msgs", "rtt_us"],
}
ENVELOPE = ("v", "wall", "sim", "ev")

path, coverage = sys.argv[1], sys.argv[2]
fail = 0
seen = set()
lines = 0
for i, line in enumerate(open(path), 1):
    line = line.strip()
    if not line:
        continue
    lines += 1
    if "NaN" in line:
        print(f"{path}:{i}: literal NaN (non-finite floats must encode as null)")
        fail = 1
    try:
        m = json.loads(line)
    except ValueError as e:
        print(f"{path}:{i}: unparseable JSON: {e}")
        fail = 1
        continue
    for k in ENVELOPE:
        if k not in m:
            print(f"{path}:{i}: missing envelope key {k!r}")
            fail = 1
    if m.get("v") != 1:
        print(f"{path}:{i}: unknown schema version {m.get('v')!r}")
        fail = 1
        continue
    ev = m.get("ev")
    keys = REQUIRED.get(ev)
    if keys is None:
        print(f"{path}:{i}: unknown event {ev!r}")
        fail = 1
        continue
    seen.add(ev)
    for k in keys:
        if k not in m:
            print(f"{path}:{i}: {ev!r} missing required key {k!r}")
            fail = 1
    extra = set(m) - set(keys) - set(ENVELOPE)
    if extra:
        print(f"{path}:{i}: {ev!r} has undeclared keys {sorted(extra)}")
        fail = 1
if lines == 0:
    print(f"{path}: empty journal")
    fail = 1
if coverage == "full":
    missing = set(REQUIRED) - seen
    if missing:
        print(f"{path}: event types never exercised: {sorted(missing)}")
        fail = 1
sys.exit(fail)
PY
status=$?
[ -n "$cleanup" ] && rm -f "$cleanup"

if [ "$status" -ne 0 ]; then
    echo "trace schema check FAILED ($journal)"
    exit 1
fi
echo "trace schema check OK ($journal)"
