#!/usr/bin/env bash
# Run the static determinism/protocol analyzer (`noloco analyze`,
# rules R1-R5) and validate its JSON output against the analyze schema
# (v1). This is the blocking CI gate for the determinism invariants
# documented in docs/ARCHITECTURE.md.
#
# With a Rust toolchain available: runs `cargo run -- analyze --format
# json` over rust/src and fails on any finding, so the committed tree
# must stay clean. Without one (minimal containers): validates the
# checked-in `docs/analyze.sample.jsonl` instead — a deliberately
# non-clean example, so both the header and the finding line shapes
# stay covered (internal consistency is checked, cleanliness is not).
#
# The schema below mirrors render_json() in rust/src/analyze/mod.rs —
# change them together.
#
# Usage: scripts/check_analyze.sh [report.jsonl]

set -u
cd "$(dirname "$0")/.."

report="${1:-}"
require_clean="no"
cleanup=""
if [ -z "$report" ]; then
    if command -v cargo >/dev/null 2>&1; then
        require_clean="yes"
        report="$(mktemp -t noloco_analyze_XXXXXX.jsonl)"
        cleanup="$report"
        # `analyze` exits 1 on findings; capture the report either way
        # and let the validator (plus require_clean) produce the
        # diagnostic. Exit 2 (walk/parse error) is fatal here.
        (cd rust && cargo run --release --quiet -- analyze --format json >"$report")
        status=$?
        if [ "$status" -gt 1 ]; then
            echo "analyze check FAILED (analyzer error, exit $status)"
            cat "$report"
            rm -f "$cleanup"
            exit 1
        fi
    else
        report="docs/analyze.sample.jsonl"
        echo "no cargo toolchain; validating checked-in $report"
    fi
fi

python3 - "$report" "$require_clean" <<'PY'
import json
import sys

# Mirror of render_json() in rust/src/analyze/mod.rs.
HEADER = ("v", "kind", "version", "files", "findings", "clean")
FINDING = ("v", "kind", "file", "line", "rule", "msg")
RULES = {"R1", "R2", "R3", "R4", "R5"}

path, require_clean = sys.argv[1], sys.argv[2]
fail = 0
header = None
nfindings = 0
for i, line in enumerate(open(path), 1):
    line = line.strip()
    if not line:
        continue
    try:
        m = json.loads(line)
    except ValueError as e:
        print(f"{path}:{i}: unparseable JSON: {e}")
        fail = 1
        continue
    if m.get("v") != 1:
        print(f"{path}:{i}: unknown schema version {m.get('v')!r}")
        fail = 1
        continue
    kind = m.get("kind")
    if i == 1:
        if kind != "analyze":
            print(f"{path}:{i}: first line must be the analyze header, got {kind!r}")
            fail = 1
            continue
        header = m
        for k in HEADER:
            if k not in m:
                print(f"{path}:{i}: header missing key {k!r}")
                fail = 1
        if not isinstance(m.get("clean"), bool):
            print(f"{path}:{i}: 'clean' must be a bool")
            fail = 1
        continue
    if kind != "finding":
        print(f"{path}:{i}: expected a finding line, got kind {kind!r}")
        fail = 1
        continue
    nfindings += 1
    for k in FINDING:
        if k not in m:
            print(f"{path}:{i}: finding missing key {k!r}")
            fail = 1
    if m.get("rule") not in RULES:
        print(f"{path}:{i}: unknown rule {m.get('rule')!r}")
        fail = 1
    if not (isinstance(m.get("line"), int) and m["line"] >= 1):
        print(f"{path}:{i}: finding 'line' must be a positive integer")
        fail = 1
if header is None:
    print(f"{path}: empty report (no analyze header)")
    sys.exit(1)
if header.get("findings") != nfindings:
    print(f"{path}: header claims {header.get('findings')!r} findings, saw {nfindings}")
    fail = 1
if header.get("clean") != (nfindings == 0):
    print(f"{path}: header 'clean' inconsistent with {nfindings} finding line(s)")
    fail = 1
if require_clean == "yes" and nfindings != 0:
    print(f"{path}: tree is NOT clean ({nfindings} finding(s)) — fix or annotate:")
    for line in open(path):
        line = line.strip()
        if not line:
            continue
        try:
            m = json.loads(line)
        except ValueError:
            continue
        if m.get("kind") == "finding":
            print(f"  {m.get('file')}:{m.get('line')}: [{m.get('rule')}] {m.get('msg')}")
    fail = 1
sys.exit(fail)
PY
status=$?
[ -n "$cleanup" ] && rm -f "$cleanup"

if [ "$status" -ne 0 ]; then
    echo "analyze check FAILED ($report)"
    exit 1
fi
echo "analyze check OK ($report)"
